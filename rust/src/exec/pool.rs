//! Work-stealing worker pools: the server-shared [`SharedPool`] with
//! per-deployment thread budgets, and the standalone [`WorkerPool`] facade.
//!
//! `rayon`/`crossbeam` are unavailable offline, so this implements the small
//! core the execution and serving layers need: N persistent workers, one
//! FIFO task queue per registered *deployment* (a [`PoolClient`]), and a
//! budget-aware claim rule that decides which deployment a free worker
//! serves next. One `SharedPool` is owned by a whole
//! [`crate::coordinator::Server`]; every deployed model registers a client
//! on it instead of spawning a private pool, so a multi-model edge device
//! runs exactly one set of exec threads.
//!
//! # Budgets and stealing
//!
//! Each client registers with a thread *budget* — the number of workers it
//! is entitled to under contention. The claim rule has two tiers:
//!
//! 1. **Under budget first.** Deployments with queued work and
//!    `active < budget` are served before anything else; among them the one
//!    with the smallest weighted virtual time (`vtime`, advanced by
//!    `1/budget` per claimed task) wins, so service rates converge to the
//!    budget ratios even when instantaneous concurrency cannot express them
//!    (e.g. a 1-worker pool shared by two deployments).
//! 2. **Steal only from idle budgets.** A deployment whose budget is
//!    exhausted may claim a worker only when tier 1 is empty — i.e. every
//!    other deployment with remaining budget has nothing queued. The spare
//!    capacity a steal consumes is therefore always some idle deployment's
//!    budget, and is handed back the moment that deployment enqueues work
//!    (its tasks re-enter tier 1 and win the next free workers).
//!
//! # Batch-aware claiming
//!
//! A free worker claims up to [`PoolConfig::claim_limit`] tasks of the
//! *same deployment* under one lock acquisition when its queue is deep
//! (many-chunk flushes otherwise pay one mutex round-trip per shard). Two
//! guards keep the scheduler's contracts intact:
//!
//! * **Fairness**: vtime advances `k/budget` for a k-task claim, and `k`
//!   is capped so the claimer never overtakes the next-lowest-vtime
//!   contender in its tier by more than one claim's worth — under
//!   contention batching degenerates to claim-1 and the PR 3 weighted-fair
//!   ordering is unchanged; only an *uncontended* deep queue batches.
//! * **Stealing**: tier-2 (budget-exhausted) claims are always single-task,
//!   so stolen capacity is handed back at the same granularity as before —
//!   a steal can never lock up k tasks' worth of an idle budget.
//!
//! `k` is additionally capped at `ceil(queue/threads)` so one worker
//! cannot swallow a whole flush that the other workers should parallelize.
//!
//! # Straggler give-back
//!
//! Those caps bound batch claims *statistically*; they cannot stop one
//! claim from serializing k−1 fast tasks behind a slow first one — the
//! systematic case being a LITTLE-pinned worker batch-claiming a flush's
//! contiguous big-weighted chunks, or an early-exit engine's variable-cost
//! shards (DESIGN.md §11). With [`PoolConfig::give_back_after`] set, a
//! worker that has run at least one task of a claim checks the claim's age
//! before each further task and, past the deadline (scaled so slower
//! topology classes get proportionally longer), returns the **unstarted
//! tail** to the front of its deployment's queue via
//! [`PoolState::give_back`] — preserving FIFO order, rolling `vtime` back
//! by `returned/budget` (the deployment must not stay charged for work it
//! didn't receive), and waking the other workers. A give-back to a closed
//! deployment drops the tasks, exactly like `close` discarding its queue.
//!
//! # Affinity
//!
//! With [`PoolConfig::pin`] set, worker `w` pins itself (via
//! [`crate::exec::affinity`], Linux `sched_setaffinity`, no-op elsewhere)
//! to the core IDs of the topology class
//! [`CoreTopology::worker_assignments`] assigns it — fastest classes
//! first, the *same* assignment the shard weights derive from, so a
//! big-cluster-weighted chunk really executes on a big core. Pinning is
//! best-effort: a refused mask (restricted cpuset, foreign-device
//! topology without host core IDs) leaves that worker migratable;
//! [`SharedPool::pinned_workers`] reports how many masks stuck.
//!
//! # Design notes
//!
//! * Queues live behind one pool-wide `Mutex` rather than lock-free
//!   Chase–Lev deques. Tasks here are *shards* — tens of microseconds to
//!   milliseconds of tree traversal — so a ~20 ns lock is noise; in
//!   exchange the scheduler is obviously correct and fully safe code.
//!   Batch claiming amortizes even that where queues run deep.
//! * Workers catch task panics, so a poisoned shard can neither kill a
//!   worker thread nor deadlock a submitter; [`PoolClient::run`] re-panics
//!   on the submitting thread after the whole job has drained.
//! * A client's drop marks its queue closed and discards still-queued
//!   tasks; in-flight tasks finish first (serving tears deployments down
//!   only after draining, see `coordinator::batcher`).

use std::collections::{BTreeMap, VecDeque};
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::affinity;
use super::topology::CoreTopology;
use crate::obs::span::SpanTimer;

/// A unit of work submitted to a pool.
pub type Task = Box<dyn FnOnce() + Send + 'static>;

/// `Send`-able raw `*mut f32` wrapper for handing disjoint slice ranges to
/// pool tasks (used by `exec::parallel` and the fused batcher). Safety
/// rests on two caller-enforced invariants: the ranges written through the
/// pointer never overlap across concurrently running tasks, and the
/// pointee buffer outlives every task (readers synchronize with a
/// completion latch/counter before touching it).
#[derive(Clone, Copy)]
pub struct MutPtr(pub *mut f32);
// SAFETY: sending the raw pointer across threads is sound under the two
// invariants documented above — disjoint write ranges per task, and buffer
// lifetime guaranteed by the completion latch the spawner waits on.
unsafe impl Send for MutPtr {}

/// Process-wide count of exec worker threads ever spawned. Monotone by
/// design (never decremented on join): tests assert that deploying more
/// models onto a server adds **zero** new worker threads.
static WORKERS_SPAWNED: AtomicUsize = AtomicUsize::new(0);

/// See [`WORKERS_SPAWNED`].
pub fn worker_threads_spawned() -> usize {
    WORKERS_SPAWNED.load(Ordering::SeqCst)
}

/// Default [`PoolConfig::claim_limit`]: deep-queue claims amortize the pool
/// mutex up to 8× without letting one worker hoard a flush (the per-claim
/// `ceil(queue/threads)` cap binds first on shallow queues).
pub const DEFAULT_CLAIM_LIMIT: usize = 8;

/// Default [`PoolConfig::give_back_after`]: well above any sane shard
/// runtime (tens of µs to single-digit ms), so give-back engages only on
/// genuine stragglers and the batching amortization is untouched on the
/// happy path.
pub const DEFAULT_GIVE_BACK_AFTER: Duration = Duration::from_millis(25);

/// How a [`SharedPool`] is built: worker count, the core topology its
/// workers (and every deployment's shard weights) are laid out over,
/// whether workers pin to their assigned cluster, and the batch-claim
/// limit.
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Worker threads (min 1).
    pub threads: usize,
    /// Core topology workers are assigned over, fastest class first. Also
    /// the topology deployments derive chunk weights from (via
    /// [`SharedPool::topology`]), so plans and placement agree.
    pub topology: CoreTopology,
    /// Pin each worker to its assigned class's core IDs (module docs).
    /// Best-effort; non-Linux platforms and refused masks degrade to
    /// unpinned workers.
    pub pin: bool,
    /// Max tasks one claim may take from a deployment's queue (min 1;
    /// 1 = the pre-batching claim-per-task behavior).
    pub claim_limit: usize,
    /// Return the unstarted tail of a claimed batch once the tasks already
    /// run have overrun this deadline (module docs, "Straggler give-back").
    /// Scaled per worker by its topology class's relative speed, so a
    /// LITTLE worker is not declared a straggler merely for running at
    /// LITTLE speed. `None` disables give-back (a claimed batch always
    /// runs to completion on its claimer).
    pub give_back_after: Option<Duration>,
}

impl PoolConfig {
    /// Defaults for `threads` workers: detected host topology, no pinning,
    /// batch claiming at [`DEFAULT_CLAIM_LIMIT`].
    pub fn new(threads: usize) -> PoolConfig {
        PoolConfig {
            threads: threads.max(1),
            topology: CoreTopology::detect(),
            pin: false,
            claim_limit: DEFAULT_CLAIM_LIMIT,
            give_back_after: Some(DEFAULT_GIVE_BACK_AFTER),
        }
    }

    /// Builder: replace the topology.
    pub fn topology(mut self, topo: CoreTopology) -> PoolConfig {
        self.topology = topo;
        self
    }

    /// Builder: enable/disable worker pinning.
    pub fn pin(mut self, pin: bool) -> PoolConfig {
        self.pin = pin;
        self
    }

    /// Builder: set the batch-claim limit (min 1).
    pub fn claim_limit(mut self, k: usize) -> PoolConfig {
        self.claim_limit = k.max(1);
        self
    }

    /// Builder: set (or disable, with `None`) the straggler give-back
    /// deadline.
    pub fn give_back_after(mut self, after: Option<Duration>) -> PoolConfig {
        self.give_back_after = after;
        self
    }
}

/// Claim-batch size distribution slots: slot `i` counts claims that took
/// `i + 1` tasks; the last slot aggregates claims of `>= CLAIM_SIZE_SLOTS`
/// tasks (the default claim limit is well below it).
pub const CLAIM_SIZE_SLOTS: usize = 16;

/// Per-deployment scheduling state.
struct DeploymentQueue {
    queue: VecDeque<Task>,
    /// The label the owning client registered under (introspection only).
    label: String,
    /// Worker entitlement under contention (≥ 1).
    budget: usize,
    /// Workers currently executing this deployment's tasks.
    active: usize,
    /// Set when the owning client dropped; the entry is removed once the
    /// last in-flight task finishes.
    closed: bool,
    /// Weighted-fair virtual time: advanced by `1/budget` per claim, so
    /// under contention claim counts converge to budget ratios.
    vtime: f64,
}

#[derive(Default)]
struct PoolState {
    deployments: BTreeMap<u64, DeploymentQueue>,
    /// Tier-2 claims (work taken from a budget-exhausted deployment by
    /// stealing an idle budget's capacity) since pool start. Plain fields:
    /// every increment already holds the pool mutex.
    steals: u64,
    /// Claimed batches whose unstarted tail came back on deadline overrun,
    /// and the tasks returned across them (module docs, "Straggler
    /// give-back"). Plain fields like `steals`: increments hold the mutex.
    give_backs: u64,
    given_back_tasks: u64,
    /// See [`CLAIM_SIZE_SLOTS`].
    claim_sizes: [u64; CLAIM_SIZE_SLOTS],
}

/// Lowest-vtime deployment with queued work in the given tier
/// (`under == true`: still under budget; `false`: budget exhausted).
fn pick(deployments: &BTreeMap<u64, DeploymentQueue>, under: bool) -> Option<u64> {
    let mut best: Option<(u64, f64)> = None;
    for (&tag, d) in deployments {
        if d.queue.is_empty() || (d.active < d.budget) != under {
            continue;
        }
        if best.map_or(true, |(_, bv)| d.vtime < bv) {
            best = Some((tag, d.vtime));
        }
    }
    best.map(|(tag, _)| tag)
}

impl PoolState {
    /// Claim up to `limit` tasks of one deployment for a free worker (see
    /// the module docs' claim and batching rules). The claimer counts as
    /// **one** active worker regardless of how many tasks it holds;
    /// `threads` is the pool size, bounding the per-claim share of a queue.
    fn claim_many(&mut self, limit: usize, threads: usize) -> Option<(u64, Vec<Task>)> {
        if let Some(tag) = pick(&self.deployments, true) {
            // Fairness cap: the next-lowest vtime among the *other* tier-1
            // contenders. Claiming k advances vtime by k/budget; k is
            // capped so the post-claim vtime overtakes that runner-up by
            // at most one claim's worth — under contention this
            // degenerates to the PR 3 claim-1 interleaving.
            let next = self
                .deployments
                .iter()
                .filter(|(&t, d)| t != tag && !d.queue.is_empty() && d.active < d.budget)
                .map(|(_, d)| d.vtime)
                .fold(f64::INFINITY, f64::min);
            let d = self.deployments.get_mut(&tag).expect("picked tag exists");
            let qlen = d.queue.len();
            let mut k = limit.max(1).min(qlen.div_ceil(threads.max(1))).max(1).min(qlen);
            if next.is_finite() {
                let fair = ((next - d.vtime) * d.budget as f64).floor() + 1.0;
                // `as usize` saturates; fair ≥ 1 because vtime ≤ next for
                // the picked (lowest-vtime) deployment.
                k = k.min((fair.max(1.0)) as usize);
            }
            let tasks: Vec<Task> =
                (0..k).map(|_| d.queue.pop_front().expect("picked queue non-empty")).collect();
            d.active += 1;
            d.vtime += k as f64 / d.budget as f64;
            self.claim_sizes[k.min(CLAIM_SIZE_SLOTS) - 1] += 1;
            return Some((tag, tasks));
        }
        // Tier 2 — stealing from idle budgets: always single-task, so the
        // stolen capacity returns at the same granularity as pre-batching.
        let tag = pick(&self.deployments, false)?;
        let d = self.deployments.get_mut(&tag).expect("picked tag exists");
        let task = d.queue.pop_front().expect("picked queue non-empty");
        d.active += 1;
        d.vtime += 1.0 / d.budget as f64;
        self.steals += 1;
        self.claim_sizes[0] += 1;
        Some((tag, vec![task]))
    }

    /// A worker returns the unstarted tail of a claimed batch (deadline
    /// overrun — see `worker_loop` and the module docs). The tasks go back
    /// to the *front* of their deployment's queue in original order, so
    /// FIFO submission order is preserved for the next claimer, and vtime
    /// rolls back by `returned/budget`: the claim charged `k/budget` up
    /// front, and a deployment must not stay charged for service it never
    /// received (the weighted-fair ratios would otherwise under-serve every
    /// deployment that ever gave back). Returns how many tasks re-queued.
    ///
    /// A closed (or already reaped) deployment drops the tasks instead —
    /// `close` discarded its queue, and the returned tail is reaped through
    /// exactly the same rule, never double-executed.
    fn give_back(&mut self, tag: u64, tasks: Vec<Task>) -> usize {
        let n = tasks.len();
        if n == 0 {
            return 0;
        }
        let d = match self.deployments.get_mut(&tag) {
            Some(d) if !d.closed => d,
            _ => return 0,
        };
        for t in tasks.into_iter().rev() {
            d.queue.push_front(t);
        }
        d.vtime -= n as f64 / d.budget as f64;
        self.give_backs += 1;
        self.given_back_tasks += n as u64;
        n
    }

    /// Add a deployment entry ([`SharedPool::register`] under the lock).
    fn register(&mut self, tag: u64, label: &str, budget: usize) {
        self.deployments.insert(
            tag,
            DeploymentQueue {
                queue: VecDeque::new(),
                label: label.to_string(),
                budget,
                active: 0,
                closed: false,
                vtime: 0.0,
            },
        );
    }

    /// Enqueue tasks for `tag` ([`PoolClient::spawn`] under the lock).
    ///
    /// WFQ catch-up: a deployment going idle → backlogged must not replay
    /// service time it never used — a stale-low vtime would let it
    /// monopolize every freed worker until it "caught up", starving the
    /// deployments that were busy all along. Raise it to the floor of the
    /// currently-backlogged vtimes before enqueueing.
    fn enqueue(&mut self, tag: u64, tasks: Vec<Task>) {
        let idle =
            self.deployments.get(&tag).map_or(true, |d| d.queue.is_empty() && d.active == 0);
        if idle {
            let floor = self
                .deployments
                .values()
                .filter(|d| !d.queue.is_empty() || d.active > 0)
                .map(|d| d.vtime)
                .fold(f64::INFINITY, f64::min);
            if floor.is_finite() {
                let d = self.deployments.get_mut(&tag).expect("client is registered");
                d.vtime = d.vtime.max(floor);
            }
        }
        let d = self.deployments.get_mut(&tag).expect("client is registered");
        for t in tasks {
            d.queue.push_back(t);
        }
    }

    /// A worker finished a claim for `tag` (the post-execution block of
    /// `worker_loop`): release the active slot and reap the entry if its
    /// client closed and nothing is left.
    fn finish(&mut self, tag: u64) {
        let gone = match self.deployments.get_mut(&tag) {
            Some(d) => {
                d.active -= 1;
                d.closed && d.active == 0 && d.queue.is_empty()
            }
            None => false,
        };
        if gone {
            self.deployments.remove(&tag);
        }
    }

    /// The client for `tag` dropped ([`PoolClient::drop`] under the lock):
    /// discard queued tasks and remove the entry now if idle, else mark it
    /// closed for the last finishing worker to reap.
    fn close(&mut self, tag: u64) {
        let gone = match self.deployments.get_mut(&tag) {
            Some(d) => {
                d.closed = true;
                d.queue.clear();
                d.active == 0
            }
            None => false,
        };
        if gone {
            self.deployments.remove(&tag);
        }
    }
}

struct Shared {
    state: Mutex<PoolState>,
    wakeup: Condvar,
    shutdown: AtomicBool,
    next_tag: AtomicU64,
    /// Live registered clients (deployments).
    registered: AtomicUsize,
    /// Worker count (bounds the per-claim queue share).
    threads: usize,
    /// Max tasks per claim ([`PoolConfig::claim_limit`]).
    claim_limit: usize,
    /// Workers whose affinity mask the kernel accepted.
    pinned: AtomicUsize,
    /// Claim-amortization counters: lock acquisitions that claimed work,
    /// and tasks claimed in total (ratio > 1 ⇔ batching engaged).
    claims: AtomicU64,
    claimed_tasks: AtomicU64,
}

/// Source of unique pool tokens (see [`SharedPool::token`]).
static NEXT_POOL_TOKEN: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// `(pool token, topology class)` of the pool worker running on this
    /// thread (`None` off worker threads). `exec::feedback` reads it to
    /// attribute a measured shard to the class that *executed* it — the
    /// claim rule makes no promise about which worker takes which chunk,
    /// so plan-slot attribution would blend cluster speeds. The token lets
    /// a consumer reject class indices from a *different* pool's topology
    /// (class numberings are only comparable within one pool).
    static WORKER_CLASS: std::cell::Cell<Option<(u64, usize)>> = std::cell::Cell::new(None);
}

/// `(pool token, topology class)` of the calling pool worker thread, if
/// any. Compare the token against [`SharedPool::token`] before trusting
/// the class index.
pub fn current_worker_class() -> Option<(u64, usize)> {
    WORKER_CLASS.with(|c| c.get())
}

fn worker_loop(
    shared: Arc<Shared>,
    token: u64,
    class: usize,
    pin_cores: Vec<usize>,
    give_back_after: Option<Duration>,
) {
    WORKER_CLASS.with(|c| c.set(Some((token, class))));
    if !pin_cores.is_empty() && affinity::pin_to_cores(&pin_cores) {
        shared.pinned.fetch_add(1, Ordering::SeqCst);
    }
    loop {
        // The `claim` span covers lock acquisition plus the claim rule,
        // restarted after each condvar wait so parked (idle) time never
        // counts. Tracing off: the timer is one atomic load.
        let (tag, tasks, claim_span) = {
            let mut span = SpanTimer::start("claim");
            let mut state = shared.state.lock().unwrap();
            let claimed = loop {
                if let Some(claimed) = state.claim_many(shared.claim_limit, shared.threads) {
                    break claimed;
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    return;
                }
                state = shared.wakeup.wait(state).unwrap();
                span = SpanTimer::start("claim");
            };
            (claimed.0, claimed.1, span)
        };
        claim_span.finish_with("tasks", tasks.len() as f64);
        shared.claims.fetch_add(1, Ordering::Relaxed);
        shared.claimed_tasks.fetch_add(tasks.len() as u64, Ordering::Relaxed);
        // Panics must not kill the worker (or abandon the rest of a batch
        // claim): `run` observes them via its latch wrapper; `spawn`
        // callers handle completion themselves (e.g. the batcher's chunk
        // guard).
        //
        // Straggler give-back: once at least one task has run, the claim's
        // age is checked before each further task; past the deadline the
        // unstarted tail goes back to the deployment's queue for the other
        // workers (module docs). At least one task always runs per claim,
        // so progress is guaranteed even at `Duration::ZERO`.
        let claimed_at = Instant::now();
        let mut tasks = tasks.into_iter();
        let mut ran = 0usize;
        loop {
            if ran > 0
                && !tasks.as_slice().is_empty()
                && give_back_after.map_or(false, |dl| claimed_at.elapsed() > dl)
            {
                let rest: Vec<Task> = tasks.collect();
                let returned = shared.state.lock().unwrap().give_back(tag, rest);
                if returned > 0 {
                    shared.wakeup.notify_all();
                }
                break;
            }
            match tasks.next() {
                Some(task) => {
                    let _ = panic::catch_unwind(AssertUnwindSafe(task));
                    ran += 1;
                }
                None => break,
            }
        }
        shared.state.lock().unwrap().finish(tag);
    }
}

/// Completion latch for one blocking job ([`PoolClient::run`]).
struct Latch {
    state: Mutex<LatchState>,
    done: Condvar,
}

struct LatchState {
    remaining: usize,
    panicked: bool,
}

impl Latch {
    fn new(n: usize) -> Latch {
        Latch {
            state: Mutex::new(LatchState { remaining: n, panicked: false }),
            done: Condvar::new(),
        }
    }

    fn complete(&self, panicked: bool) {
        let mut s = self.state.lock().unwrap();
        s.remaining -= 1;
        s.panicked |= panicked;
        if s.remaining == 0 {
            self.done.notify_all();
        }
    }

    /// Wait for the whole job; report whether any task panicked.
    fn wait(&self) -> bool {
        let mut s = self.state.lock().unwrap();
        while s.remaining > 0 {
            s = self.done.wait(s).unwrap();
        }
        s.panicked
    }
}

/// Claim-amortization and give-back counters ([`SharedPool::claim_stats`]).
/// Cheap relative to the full [`PoolStats`] snapshot — the hot-path gauges
/// benches poll in a loop.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ClaimStats {
    /// Lock acquisitions that claimed work.
    pub claims: u64,
    /// Tasks claimed in total (ratio to `claims` > 1 ⇔ batching engaged).
    pub claimed_tasks: u64,
    /// Claimed batches whose unstarted tail was returned on deadline
    /// overrun (module docs, "Straggler give-back").
    pub give_backs: u64,
    /// Tasks returned across those give-backs.
    pub given_back_tasks: u64,
}

/// Point-in-time snapshot of one deployment's scheduling state
/// ([`SharedPool::stats`]).
#[derive(Debug, Clone)]
pub struct DeploymentStats {
    /// Label the owning client registered under.
    pub label: String,
    pub budget: usize,
    /// Tasks waiting in this deployment's queue (the queue-depth gauge).
    pub queue_depth: usize,
    /// Workers currently executing its tasks.
    pub active: usize,
    pub vtime: f64,
    /// Gap to the lowest vtime across registered deployments — how far
    /// behind the weighted-fair frontier this deployment's service
    /// history sits (0 for the frontier holder).
    pub vtime_lag: f64,
}

/// Point-in-time pool snapshot ([`SharedPool::stats`]): the scheduler
/// internals PR 3–5 made load-bearing but left invisible.
#[derive(Debug, Clone)]
pub struct PoolStats {
    pub threads: usize,
    /// Workers whose affinity mask the kernel accepted.
    pub pinned: usize,
    /// Lock acquisitions that claimed work.
    pub claims: u64,
    /// Tasks claimed in total (ratio to `claims` > 1 ⇔ batching engaged).
    pub claimed_tasks: u64,
    /// Tier-2 claims that stole an idle budget's capacity.
    pub steals: u64,
    /// Claimed batches whose unstarted tail was returned on deadline
    /// overrun (module docs, "Straggler give-back").
    pub give_backs: u64,
    /// Tasks returned across those give-backs.
    pub given_back_tasks: u64,
    /// Claim-batch size distribution; slot `i` counts claims of `i + 1`
    /// tasks, last slot aggregates the tail ([`CLAIM_SIZE_SLOTS`]).
    pub claim_sizes: Vec<u64>,
    pub deployments: Vec<DeploymentStats>,
}

impl PoolStats {
    /// Machine-readable form (embedded in `Server::stats_json`).
    pub fn to_json(&self) -> crate::util::Json {
        use crate::util::Json;
        let claim_sizes = Json::Arr(self.claim_sizes.iter().map(|&c| Json::Num(c as f64)).collect());
        let deployments = Json::Arr(
            self.deployments
                .iter()
                .map(|d| {
                    Json::from_pairs(vec![
                        ("label", Json::Str(d.label.clone())),
                        ("budget", Json::Num(d.budget as f64)),
                        ("queue_depth", Json::Num(d.queue_depth as f64)),
                        ("active", Json::Num(d.active as f64)),
                        ("vtime", Json::Num(d.vtime)),
                        ("vtime_lag", Json::Num(d.vtime_lag)),
                    ])
                })
                .collect(),
        );
        Json::from_pairs(vec![
            ("threads", Json::Num(self.threads as f64)),
            ("pinned", Json::Num(self.pinned as f64)),
            ("claims", Json::Num(self.claims as f64)),
            ("claimed_tasks", Json::Num(self.claimed_tasks as f64)),
            ("steals", Json::Num(self.steals as f64)),
            ("give_backs", Json::Num(self.give_backs as f64)),
            ("given_back_tasks", Json::Num(self.given_back_tasks as f64)),
            ("claim_sizes", claim_sizes),
            ("deployments", deployments),
        ])
    }
}

/// A pool of work-stealing workers shared by many deployments.
///
/// Workers are *additional* threads: a pool with `threads` workers runs
/// that many, and a thread blocking in [`PoolClient::run`] does not execute
/// tasks, so `threads` is the total compute parallelism available to every
/// registered deployment combined.
pub struct SharedPool {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    threads: usize,
    topology: CoreTopology,
    /// Process-unique identity for this pool's topology/class numbering
    /// (matched against [`current_worker_class`] samples).
    token: u64,
}

impl SharedPool {
    /// Spawn a pool with `threads` workers (min 1) over the detected host
    /// topology — no pinning, default batch claiming.
    pub fn new(threads: usize) -> Arc<SharedPool> {
        Self::with_config(PoolConfig::new(threads))
    }

    /// Spawn a pool per an explicit [`PoolConfig`] (topology, pinning,
    /// batch-claim limit).
    pub fn with_config(config: PoolConfig) -> Arc<SharedPool> {
        let threads = config.threads.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState::default()),
            wakeup: Condvar::new(),
            shutdown: AtomicBool::new(false),
            next_tag: AtomicU64::new(0),
            registered: AtomicUsize::new(0),
            threads,
            claim_limit: config.claim_limit.max(1),
            pinned: AtomicUsize::new(0),
            claims: AtomicU64::new(0),
            claimed_tasks: AtomicU64::new(0),
        });
        // relaxed: unique-ID allocation — only atomicity matters, no other
        // memory is published under this counter.
        let token = NEXT_POOL_TOKEN.fetch_add(1, Ordering::Relaxed);
        let assignments = config.topology.worker_assignments(threads);
        // Fastest class's weight: the give-back deadline is calibrated for
        // it and stretched by the speed ratio for slower classes, so a
        // LITTLE worker gets proportionally longer before its first task
        // counts as a straggler.
        let w_max = assignments.iter().map(|a| a.weight).fold(1.0f64, f64::max);
        let workers = (0..threads)
            .map(|w| {
                let shared = shared.clone();
                let class = assignments[w].class;
                let pin_cores = if config.pin {
                    config.topology.classes[class].core_ids.clone()
                } else {
                    Vec::new()
                };
                let give_back_after = config
                    .give_back_after
                    .map(|base| base.mul_f64((w_max / assignments[w].weight.max(1e-9)).max(1.0)));
                WORKERS_SPAWNED.fetch_add(1, Ordering::SeqCst);
                std::thread::Builder::new()
                    .name(format!("exec-worker-{w}"))
                    .spawn(move || worker_loop(shared, token, class, pin_cores, give_back_after))
                    .expect("spawn exec worker")
            })
            .collect();
        Arc::new(SharedPool { shared, workers, threads, topology: config.topology, token })
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The core topology this pool's workers are assigned over — the one
    /// deployments should derive chunk weights from, so plan and placement
    /// agree.
    pub fn topology(&self) -> &CoreTopology {
        &self.topology
    }

    /// Workers whose affinity mask the kernel accepted (0 when pinning is
    /// off or unsupported).
    pub fn pinned_workers(&self) -> usize {
        self.shared.pinned.load(Ordering::SeqCst)
    }

    /// Process-unique identity of this pool's topology/class numbering —
    /// class indices from [`current_worker_class`] are only meaningful
    /// when their token equals this one.
    pub fn token(&self) -> u64 {
        self.token
    }

    /// Claim-amortization and give-back counters. A `claimed_tasks /
    /// claims` ratio above 1 means batch claiming engaged; non-zero
    /// `give_backs` means the straggler deadline fired.
    pub fn claim_stats(&self) -> ClaimStats {
        let (give_backs, given_back_tasks) = {
            let state = self.shared.state.lock().unwrap();
            (state.give_backs, state.given_back_tasks)
        };
        ClaimStats {
            claims: self.shared.claims.load(Ordering::Relaxed),
            claimed_tasks: self.shared.claimed_tasks.load(Ordering::Relaxed),
            give_backs,
            given_back_tasks,
        }
    }

    /// Live registered clients (deployments sharing this pool).
    pub fn registered(&self) -> usize {
        self.shared.registered.load(Ordering::SeqCst)
    }

    /// Rich scheduler introspection: pool-wide claim/steal counters, the
    /// claim-batch size distribution, and each deployment's queue depth,
    /// active workers and weighted-fair vtime (with its lag to the
    /// frontier). One lock acquisition; values form a consistent snapshot.
    pub fn stats(&self) -> PoolStats {
        let state = self.shared.state.lock().unwrap();
        let floor =
            state.deployments.values().map(|d| d.vtime).fold(f64::INFINITY, f64::min);
        let deployments = state
            .deployments
            .values()
            .map(|d| DeploymentStats {
                label: d.label.clone(),
                budget: d.budget,
                queue_depth: d.queue.len(),
                active: d.active,
                vtime: d.vtime,
                vtime_lag: if floor.is_finite() { d.vtime - floor } else { 0.0 },
            })
            .collect();
        PoolStats {
            threads: self.threads,
            pinned: self.shared.pinned.load(Ordering::SeqCst),
            claims: self.shared.claims.load(Ordering::Relaxed),
            claimed_tasks: self.shared.claimed_tasks.load(Ordering::Relaxed),
            steals: state.steals,
            give_backs: state.give_backs,
            given_back_tasks: state.given_back_tasks,
            claim_sizes: state.claim_sizes.to_vec(),
            deployments,
        }
    }

    /// Register a deployment with a thread `budget` (clamped to ≥ 1; may
    /// exceed [`SharedPool::threads`], in which case it is simply never the
    /// binding constraint). The client's vtime joins the live virtual
    /// clock at its first [`PoolClient::spawn`] (see the catch-up rule
    /// there), so the initial value here is immaterial.
    ///
    /// Associated function (the client keeps the pool alive, so it needs
    /// the `Arc`, and `self: &Arc<Self>` receivers are not stable Rust).
    pub fn register(pool: &Arc<SharedPool>, label: &str, budget: usize) -> PoolClient {
        // relaxed: unique-ID allocation; the deployment entry itself is
        // published under the pool mutex below, not under this counter.
        let tag = pool.shared.next_tag.fetch_add(1, Ordering::Relaxed);
        let budget = budget.max(1);
        pool.shared.state.lock().unwrap().register(tag, label, budget);
        pool.shared.registered.fetch_add(1, Ordering::SeqCst);
        PoolClient { pool: pool.clone(), tag, budget, label: label.to_string() }
    }
}

impl Drop for SharedPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        // Wake everyone so parked workers observe the flag.
        let _guard = self.shared.state.lock().unwrap();
        self.shared.wakeup.notify_all();
        drop(_guard);
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// A deployment's handle onto a [`SharedPool`]: the tagged queue tasks are
/// submitted through. Dropping the client unregisters the deployment
/// (still-queued tasks are discarded; in-flight tasks finish).
pub struct PoolClient {
    pool: Arc<SharedPool>,
    tag: u64,
    budget: usize,
    label: String,
}

impl PoolClient {
    /// This deployment's thread budget.
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// The label the client registered under (diagnostics only).
    pub fn label(&self) -> &str {
        &self.label
    }

    /// The pool this client is registered on.
    pub fn pool(&self) -> &Arc<SharedPool> {
        &self.pool
    }

    /// Enqueue a batch of tasks, fire-and-forget. Callers that need
    /// completion signalling wrap the tasks themselves (see
    /// `coordinator::batcher`); callers that need blocking semantics use
    /// [`PoolClient::run`].
    pub fn spawn(&self, tasks: Vec<Task>) {
        if tasks.is_empty() {
            return;
        }
        self.pool.shared.state.lock().unwrap().enqueue(self.tag, tasks);
        self.pool.shared.wakeup.notify_all();
    }

    /// Run a job: execute every task on the pool, blocking until all have
    /// finished. Panics (after the job has fully drained) if any task
    /// panicked. Concurrent `run` calls from different threads are safe.
    pub fn run(&self, tasks: Vec<Task>) {
        let n = tasks.len();
        if n == 0 {
            return;
        }
        let latch = Arc::new(Latch::new(n));
        let wrapped: Vec<Task> = tasks
            .into_iter()
            .map(|task| {
                let latch = latch.clone();
                Box::new(move || {
                    let result = panic::catch_unwind(AssertUnwindSafe(task));
                    latch.complete(result.is_err());
                }) as Task
            })
            .collect();
        self.spawn(wrapped);
        if latch.wait() {
            panic!("exec worker task panicked");
        }
    }
}

impl Drop for PoolClient {
    fn drop(&mut self) {
        self.pool.shared.state.lock().unwrap().close(self.tag);
        self.pool.shared.registered.fetch_sub(1, Ordering::SeqCst);
    }
}

/// A standalone pool with a single anonymous deployment — the facade the
/// [`crate::exec::ParallelEngine`] and one-off callers use. Equivalent to
/// `SharedPool::new(threads)` plus one client with `budget == threads`.
pub struct WorkerPool {
    client: PoolClient,
}

impl WorkerPool {
    /// Spawn a pool with `threads` workers (min 1).
    pub fn new(threads: usize) -> WorkerPool {
        Self::with_config(PoolConfig::new(threads))
    }

    /// Spawn per an explicit [`PoolConfig`] (topology, pinning, batch
    /// claiming) — the facade `ParallelEngine` and the adaptive bench use.
    pub fn with_config(config: PoolConfig) -> WorkerPool {
        let threads = config.threads.max(1);
        let pool = SharedPool::with_config(config);
        let client = SharedPool::register(&pool, "standalone", threads);
        WorkerPool { client }
    }

    /// Number of workers.
    pub fn threads(&self) -> usize {
        self.client.pool().threads()
    }

    /// The underlying shared pool (topology / pinning / claim stats).
    pub fn pool(&self) -> &Arc<SharedPool> {
        self.client.pool()
    }

    /// See [`PoolClient::run`].
    pub fn run(&self, tasks: Vec<Task>) {
        self.client.run(tasks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn runs_every_task_exactly_once() {
        let pool = WorkerPool::new(4);
        let hits = Arc::new(AtomicU64::new(0));
        let tasks: Vec<Task> = (0..500)
            .map(|i| {
                let hits = hits.clone();
                Box::new(move || {
                    hits.fetch_add(i + 1, Ordering::Relaxed);
                }) as Task
            })
            .collect();
        pool.run(tasks);
        // Sum 1..=500 — each task ran exactly once.
        assert_eq!(hits.load(Ordering::Relaxed), 500 * 501 / 2);
    }

    #[test]
    fn stealing_drains_imbalanced_load() {
        // One long task plus many short ones: with work conservation, total
        // wall time is bounded by the long task, and everything completes.
        let pool = WorkerPool::new(4);
        let done = Arc::new(AtomicU64::new(0));
        let mut tasks: Vec<Task> = Vec::new();
        for i in 0..64 {
            let done = done.clone();
            tasks.push(Box::new(move || {
                if i == 0 {
                    std::thread::sleep(Duration::from_millis(20));
                }
                done.fetch_add(1, Ordering::Relaxed);
            }));
        }
        pool.run(tasks);
        assert_eq!(done.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn sequential_jobs_reuse_workers() {
        let pool = WorkerPool::new(2);
        let hits = Arc::new(AtomicU64::new(0));
        for _ in 0..20 {
            let tasks: Vec<Task> = (0..8)
                .map(|_| {
                    let hits = hits.clone();
                    Box::new(move || {
                        hits.fetch_add(1, Ordering::Relaxed);
                    }) as Task
                })
                .collect();
            pool.run(tasks);
        }
        assert_eq!(hits.load(Ordering::Relaxed), 160);
    }

    #[test]
    fn concurrent_submitters() {
        let pool = Arc::new(WorkerPool::new(4));
        let hits = Arc::new(AtomicU64::new(0));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let pool = pool.clone();
            let hits = hits.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..10 {
                    let tasks: Vec<Task> = (0..16)
                        .map(|_| {
                            let hits = hits.clone();
                            Box::new(move || {
                                hits.fetch_add(1, Ordering::Relaxed);
                            }) as Task
                        })
                        .collect();
                    pool.run(tasks);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(hits.load(Ordering::Relaxed), 4 * 10 * 16);
    }

    #[test]
    fn task_panic_propagates_after_drain() {
        let pool = WorkerPool::new(2);
        let done = Arc::new(AtomicU64::new(0));
        let mut tasks: Vec<Task> = Vec::new();
        for i in 0..16 {
            let done = done.clone();
            tasks.push(Box::new(move || {
                if i == 3 {
                    panic!("boom");
                }
                done.fetch_add(1, Ordering::Relaxed);
            }));
        }
        let result = panic::catch_unwind(AssertUnwindSafe(|| pool.run(tasks)));
        assert!(result.is_err());
        // Every non-panicking task still ran (no abandoned work).
        assert_eq!(done.load(Ordering::Relaxed), 15);
        // The pool survives for the next job.
        let hits = Arc::new(AtomicU64::new(0));
        let h2 = hits.clone();
        pool.run(vec![Box::new(move || {
            h2.fetch_add(1, Ordering::Relaxed);
        })]);
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn single_thread_pool_works() {
        let pool = WorkerPool::new(1);
        let hits = Arc::new(AtomicU64::new(0));
        let h = hits.clone();
        pool.run(vec![Box::new(move || {
            h.fetch_add(7, Ordering::Relaxed);
        })]);
        assert_eq!(hits.load(Ordering::Relaxed), 7);
    }

    #[test]
    fn register_unregister_tracks_clients() {
        let pool = SharedPool::new(2);
        assert_eq!(pool.registered(), 0);
        let a = SharedPool::register(&pool, "a", 1);
        let b = SharedPool::register(&pool, "b", 2);
        assert_eq!(pool.registered(), 2);
        assert_eq!(a.budget(), 1);
        assert_eq!(b.label(), "b");
        drop(a);
        assert_eq!(pool.registered(), 1);
        drop(b);
        assert_eq!(pool.registered(), 0);
        // Re-registering after drain works.
        let c = SharedPool::register(&pool, "c", 9);
        assert_eq!(c.budget(), 9);
        let hits = Arc::new(AtomicU64::new(0));
        let h = hits.clone();
        c.run(vec![Box::new(move || {
            h.fetch_add(1, Ordering::Relaxed);
        })]);
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn idle_budgets_are_stolen() {
        // A budget-1 client alone on a 4-worker pool may exceed its budget:
        // the other budgets are idle, so their workers steal its work.
        let pool = SharedPool::new(4);
        let _other = SharedPool::register(&pool, "idle", 3);
        let solo = SharedPool::register(&pool, "solo", 1);
        let active = Arc::new(AtomicU64::new(0));
        let peak = Arc::new(AtomicU64::new(0));
        let tasks: Vec<Task> = (0..32)
            .map(|_| {
                let active = active.clone();
                let peak = peak.clone();
                Box::new(move || {
                    let a = active.fetch_add(1, Ordering::SeqCst) + 1;
                    peak.fetch_max(a, Ordering::SeqCst);
                    std::thread::sleep(Duration::from_millis(5));
                    active.fetch_sub(1, Ordering::SeqCst);
                }) as Task
            })
            .collect();
        solo.run(tasks);
        assert!(peak.load(Ordering::SeqCst) > 1, "no stealing beyond budget");
    }

    #[test]
    fn weighted_fair_claiming_respects_budgets() {
        // One worker shared by budgets 1 and 3: claim counts must converge
        // to ~1:3, even though instantaneous concurrency is always 1.
        let pool = SharedPool::new(1);
        let a = SharedPool::register(&pool, "a", 1);
        let b = SharedPool::register(&pool, "b", 3);
        let order = Arc::new(Mutex::new(Vec::new()));
        let done = Arc::new(AtomicU64::new(0));
        // Hold the only worker while both queues fill.
        let gate = Arc::new(AtomicBool::new(false));
        {
            let gate = gate.clone();
            a.spawn(vec![Box::new(move || {
                while !gate.load(Ordering::Acquire) {
                    std::thread::sleep(Duration::from_millis(1));
                }
            }) as Task]);
        }
        let mk = |who: char| -> Task {
            let order = order.clone();
            let done = done.clone();
            Box::new(move || {
                order.lock().unwrap().push(who);
                done.fetch_add(1, Ordering::SeqCst);
            })
        };
        a.spawn((0..8).map(|_| mk('a')).collect());
        b.spawn((0..8).map(|_| mk('b')).collect());
        gate.store(true, Ordering::Release);
        while done.load(Ordering::SeqCst) < 16 {
            std::thread::sleep(Duration::from_millis(1));
        }
        let order = order.lock().unwrap();
        let b_first_8 = order[..8].iter().filter(|&&c| c == 'b').count();
        assert!(
            b_first_8 >= 5,
            "budget-3 deployment got only {b_first_8}/8 of the first claims: {order:?}"
        );
        assert_eq!(order.len(), 16);
    }

    #[test]
    fn idle_deployment_cannot_replay_unused_vtime() {
        // Regression: before the spawn-time catch-up, a long-idle client
        // kept a stale-low vtime and monopolized every freed worker until
        // it "caught up" with the busy client's service history.
        let pool = SharedPool::new(1);
        let a = SharedPool::register(&pool, "busy", 1);
        let b = SharedPool::register(&pool, "bursty", 1);
        // `a` accumulates service history while `b` sits idle.
        for _ in 0..50 {
            let h = Arc::new(AtomicU64::new(0));
            let hh = h.clone();
            a.run(vec![Box::new(move || {
                hh.fetch_add(1, Ordering::Relaxed);
            }) as Task]);
        }
        // Hold the worker, queue 4 tasks each, release: b's burst must
        // interleave with a's (~1:1 at equal budgets), not sweep the queue.
        let gate = Arc::new(AtomicBool::new(false));
        {
            let gate = gate.clone();
            a.spawn(vec![Box::new(move || {
                while !gate.load(Ordering::Acquire) {
                    std::thread::sleep(Duration::from_millis(1));
                }
            }) as Task]);
        }
        let order = Arc::new(Mutex::new(Vec::new()));
        let done = Arc::new(AtomicU64::new(0));
        let mk = |who: char| -> Task {
            let order = order.clone();
            let done = done.clone();
            Box::new(move || {
                order.lock().unwrap().push(who);
                done.fetch_add(1, Ordering::SeqCst);
            })
        };
        a.spawn((0..4).map(|_| mk('a')).collect());
        b.spawn((0..4).map(|_| mk('b')).collect());
        gate.store(true, Ordering::Release);
        while done.load(Ordering::SeqCst) < 8 {
            std::thread::sleep(Duration::from_millis(1));
        }
        let order = order.lock().unwrap();
        let b_first_4 = order[..4].iter().filter(|&&c| c == 'b').count();
        assert!(
            b_first_4 <= 3,
            "bursty deployment must not sweep the first slots: {order:?}"
        );
    }

    #[test]
    fn dropped_client_discards_queued_tasks() {
        // Queue work behind a blocker, then drop the client: queued tasks
        // are discarded, in-flight ones finish, and the pool stays healthy.
        let pool = SharedPool::new(1);
        let victim = SharedPool::register(&pool, "victim", 1);
        let survivor = SharedPool::register(&pool, "survivor", 1);
        let gate = Arc::new(AtomicBool::new(false));
        let ran = Arc::new(AtomicU64::new(0));
        {
            let gate = gate.clone();
            let ran = ran.clone();
            victim.spawn(vec![Box::new(move || {
                while !gate.load(Ordering::Acquire) {
                    std::thread::sleep(Duration::from_millis(1));
                }
                ran.fetch_add(1, Ordering::SeqCst);
            }) as Task]);
        }
        // Wait for the blocker to be claimed so it is in-flight, not queued.
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while pool.shared.state.lock().unwrap().deployments.values().all(|d| d.active == 0) {
            assert!(std::time::Instant::now() < deadline, "blocker never claimed");
            std::thread::sleep(Duration::from_millis(1));
        }
        {
            let ran = ran.clone();
            victim.spawn(vec![Box::new(move || {
                ran.fetch_add(100, Ordering::SeqCst);
            }) as Task]);
        }
        drop(victim); // discards the queued task, keeps the in-flight one
        gate.store(true, Ordering::Release);
        // The survivor still gets service.
        let hits = Arc::new(AtomicU64::new(0));
        let h = hits.clone();
        survivor.run(vec![Box::new(move || {
            h.fetch_add(1, Ordering::Relaxed);
        })]);
        assert_eq!(hits.load(Ordering::Relaxed), 1);
        // In-flight blocker ran; the queued task never did.
        assert_eq!(ran.load(Ordering::SeqCst), 1);
        assert_eq!(pool.registered(), 1);
    }

    #[test]
    fn spawned_thread_counter_monotone() {
        // `>=`: other tests in this binary spawn pools concurrently.
        let before = worker_threads_spawned();
        let _pool = SharedPool::new(3);
        assert!(worker_threads_spawned() - before >= 3);
    }

    #[test]
    fn deep_queue_batch_claims_amortize_the_lock() {
        // One worker, one deployment, 64 queued tasks behind a gate: with
        // claim_limit 8 the worker must take them in far fewer than 64
        // claims (8 at the depth heuristic's qlen/threads cap).
        let pool = SharedPool::with_config(PoolConfig::new(1).claim_limit(8));
        let client = SharedPool::register(&pool, "deep", 1);
        let gate = Arc::new(AtomicBool::new(false));
        {
            let gate = gate.clone();
            client.spawn(vec![Box::new(move || {
                while !gate.load(Ordering::Acquire) {
                    std::thread::sleep(Duration::from_millis(1));
                }
            }) as Task]);
        }
        // Wait until the blocker is in flight so the 64 tasks below are
        // claimed in a clean window (exact counter deltas).
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while pool.shared.state.lock().unwrap().deployments.values().all(|d| d.active == 0) {
            assert!(std::time::Instant::now() < deadline, "blocker never claimed");
            std::thread::sleep(Duration::from_millis(1));
        }
        let before = pool.claim_stats();
        let done = Arc::new(AtomicU64::new(0));
        let tasks: Vec<Task> = (0..64)
            .map(|_| {
                let done = done.clone();
                Box::new(move || {
                    done.fetch_add(1, Ordering::SeqCst);
                }) as Task
            })
            .collect();
        client.spawn(tasks);
        gate.store(true, Ordering::Release);
        while done.load(Ordering::SeqCst) < 64 {
            std::thread::sleep(Duration::from_millis(1));
        }
        let cs = pool.claim_stats();
        let dc = cs.claims - before.claims;
        let dt = cs.claimed_tasks - before.claimed_tasks;
        assert_eq!(dt, 64);
        assert!(dc <= 16, "64 tasks took {dc} claims — batching never engaged");
    }

    #[test]
    fn claim_limit_one_restores_task_granularity() {
        let pool = SharedPool::with_config(PoolConfig::new(1).claim_limit(1));
        let client = SharedPool::register(&pool, "one", 1);
        let done = Arc::new(AtomicU64::new(0));
        let tasks: Vec<Task> = (0..16)
            .map(|_| {
                let done = done.clone();
                Box::new(move || {
                    done.fetch_add(1, Ordering::SeqCst);
                }) as Task
            })
            .collect();
        client.run(tasks);
        let cs = pool.claim_stats();
        assert_eq!(cs.claims, cs.claimed_tasks, "claim_limit=1 must claim one task per lock");
        assert_eq!(done.load(Ordering::SeqCst), 16);
    }

    /// PR 3's weighted-fair ordering must survive batch claiming: under
    /// contention the fairness cap degenerates claims to ~1 task, so a
    /// budget-3 deployment still wins ~3/4 of the early service even
    /// though both queues are deep enough to batch.
    #[test]
    fn weighted_fairness_survives_batch_claiming() {
        let pool = SharedPool::with_config(PoolConfig::new(1).claim_limit(8));
        let a = SharedPool::register(&pool, "a", 1);
        let b = SharedPool::register(&pool, "b", 3);
        let order = Arc::new(Mutex::new(Vec::new()));
        let done = Arc::new(AtomicU64::new(0));
        let gate = Arc::new(AtomicBool::new(false));
        {
            let gate = gate.clone();
            a.spawn(vec![Box::new(move || {
                while !gate.load(Ordering::Acquire) {
                    std::thread::sleep(Duration::from_millis(1));
                }
            }) as Task]);
        }
        let mk = |who: char| -> Task {
            let order = order.clone();
            let done = done.clone();
            Box::new(move || {
                order.lock().unwrap().push(who);
                done.fetch_add(1, Ordering::SeqCst);
            })
        };
        a.spawn((0..16).map(|_| mk('a')).collect());
        b.spawn((0..16).map(|_| mk('b')).collect());
        gate.store(true, Ordering::Release);
        while done.load(Ordering::SeqCst) < 32 {
            std::thread::sleep(Duration::from_millis(1));
        }
        let order = order.lock().unwrap();
        let b_first_16 = order[..16].iter().filter(|&&c| c == 'b').count();
        assert!(
            (10..=14).contains(&b_first_16),
            "budget-3 deployment got {b_first_16}/16 of the first claims \
             (want ~12): {order:?}"
        );
    }

    /// Satellite (ISSUE 5): budget-exhausted deployments must still steal
    /// only idle budgets — and only **one task per claim** — when batch
    /// claiming is on. A deployment saturating its budget cannot have a
    /// worker batch-grab k of its tasks through the steal tier.
    #[test]
    fn steals_stay_single_task_under_batch_claiming() {
        // Worker 1 holds hog's blocker, so hog sits at its budget of 1 and
        // everything else it queues is reachable only through tier-2
        // steals of "idle"'s budget — executed by worker 2, the sole
        // claimer during the gated phase, so claim counts are exact.
        let pool = SharedPool::with_config(PoolConfig::new(2).claim_limit(8));
        let _idle = SharedPool::register(&pool, "idle", 1);
        let hog = SharedPool::register(&pool, "hog", 1);
        let gate = Arc::new(AtomicBool::new(false));
        {
            let gate = gate.clone();
            hog.spawn(vec![Box::new(move || {
                while !gate.load(Ordering::Acquire) {
                    std::thread::sleep(Duration::from_millis(1));
                }
            }) as Task]);
        }
        // Wait until the blocker is in flight (hog budget-exhausted).
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while pool.shared.state.lock().unwrap().deployments.values().all(|d| d.active == 0) {
            assert!(std::time::Instant::now() < deadline, "blocker never claimed");
            std::thread::sleep(Duration::from_millis(1));
        }
        let before = pool.claim_stats();
        let steals_before = pool.stats().steals;
        let done = Arc::new(AtomicU64::new(0));
        let tasks: Vec<Task> = (0..8)
            .map(|_| {
                let done = done.clone();
                Box::new(move || {
                    done.fetch_add(1, Ordering::SeqCst);
                }) as Task
            })
            .collect();
        hog.spawn(tasks);
        while done.load(Ordering::SeqCst) < 8 {
            std::thread::sleep(Duration::from_millis(1));
        }
        let cs = pool.claim_stats();
        let dc = cs.claims - before.claims;
        let dt = cs.claimed_tasks - before.claimed_tasks;
        assert_eq!(dt, 8);
        assert_eq!(dc, 8, "every steal must claim exactly one task, got {dt}/{dc}");
        // Each of those gated claims went through tier 2 — the steal
        // counter must say so.
        assert_eq!(pool.stats().steals - steals_before, 8, "steals must be counted");
        gate.store(true, Ordering::Release);
    }

    /// The claim-size distribution must account for every claim and every
    /// task. Expected totals derive from `claim_stats()` — the existing
    /// source of truth — and the slot arithmetic from the distribution's
    /// own length, not re-typed literals.
    #[test]
    fn claim_size_distribution_accounts_for_all_claims() {
        let pool = SharedPool::with_config(PoolConfig::new(2).claim_limit(8));
        let client = SharedPool::register(&pool, "dist", 2);
        for _ in 0..5 {
            let done = Arc::new(AtomicU64::new(0));
            let tasks: Vec<Task> = (0..32)
                .map(|_| {
                    let done = done.clone();
                    Box::new(move || {
                        done.fetch_add(1, Ordering::SeqCst);
                    }) as Task
                })
                .collect();
            client.run(tasks);
        }
        // Workers decrement `active` after the completion latch fires —
        // poll to a deadline before asserting on the idle snapshot.
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while pool.stats().deployments.iter().any(|d| d.active > 0) {
            assert!(std::time::Instant::now() < deadline, "workers never went idle");
            std::thread::sleep(Duration::from_millis(1));
        }
        let stats = pool.stats();
        let cs = pool.claim_stats();
        assert_eq!(stats.claims, cs.claims);
        assert_eq!(stats.claimed_tasks, cs.claimed_tasks);
        assert_eq!(stats.give_backs, cs.give_backs);
        assert_eq!(stats.given_back_tasks, cs.given_back_tasks);
        assert_eq!(stats.claim_sizes.len(), CLAIM_SIZE_SLOTS);
        let dist_claims: u64 = stats.claim_sizes.iter().sum();
        // claim_limit (8) is below the aggregate tail slot, so the
        // weighted sum reconstructs the claimed-task total exactly.
        let dist_tasks: u64 =
            stats.claim_sizes.iter().enumerate().map(|(i, &c)| (i as u64 + 1) * c).sum();
        assert_eq!(dist_claims, claims, "every claim lands in exactly one slot");
        assert_eq!(dist_tasks, claimed_tasks, "slot-weighted sum must equal tasks claimed");
        assert!(stats.steals <= stats.claims);
        // Per-deployment snapshot: the client is visible and idle again.
        let d = stats.deployments.iter().find(|d| d.label == "dist").expect("labelled");
        assert_eq!(d.queue_depth, 0);
        assert_eq!(d.active, 0);
        assert_eq!(d.budget, 2);
        assert!(d.vtime_lag >= 0.0);
    }

    #[test]
    fn pinned_pool_executes_and_reports() {
        // Pin workers to the first two allowed cores (cluster masks of a
        // synthetic 1+1 topology). On restricted hosts the mask may be
        // refused — the pool must work either way and the count must stay
        // within bounds.
        let topo = CoreTopology::synthetic_big_little(1, 1, 3.0);
        let pool = SharedPool::with_config(PoolConfig::new(2).topology(topo).pin(true));
        assert!(pool.pinned_workers() <= 2);
        if crate::exec::affinity::pinning_supported() {
            let allowed = crate::exec::affinity::current_affinity().unwrap_or_default();
            if allowed.contains(&0) && allowed.contains(&1) {
                // Workers pin in their startup preamble — poll with a
                // deadline instead of racing a fixed sleep.
                let deadline = std::time::Instant::now() + Duration::from_secs(5);
                while pool.pinned_workers() < 2 && std::time::Instant::now() < deadline {
                    std::thread::sleep(Duration::from_millis(2));
                }
                assert_eq!(pool.pinned_workers(), 2, "both cluster masks should stick");
            }
        }
        let client = SharedPool::register(&pool, "pinned", 2);
        let hits = Arc::new(AtomicU64::new(0));
        let h = hits.clone();
        client.run(vec![Box::new(move || {
            h.fetch_add(1, Ordering::Relaxed);
        })]);
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }

    /// Satellite (ISSUE 9): a give-back must roll vtime back by exactly
    /// `returned/budget` — the claim charged the full batch up front, and
    /// a deployment must not stay charged for service it never received.
    /// Exact deltas, no timing.
    #[test]
    fn give_back_rolls_vtime_back_exactly() {
        let runs = Arc::new(AtomicU64::new(0));
        let mk = |runs: &Arc<AtomicU64>| -> Task {
            let runs = runs.clone();
            Box::new(move || {
                runs.fetch_add(1, Ordering::SeqCst);
            })
        };
        let mut state = PoolState::default();
        state.register(1, "gb", 1);
        state.enqueue(1, (0..4).map(|_| mk(&runs)).collect());
        let (tag, mut tasks) = state.claim_many(8, 1).expect("queued work claims");
        assert_eq!(tag, 1);
        assert_eq!(tasks.len(), 4, "uncontended deep queue batches the whole flush");
        assert_eq!(state.deployments[&1].vtime, 4.0, "claim charges k/budget up front");
        // Run the first task; give back the unstarted tail.
        (tasks.remove(0))();
        assert_eq!(state.give_back(1, tasks), 3);
        assert_eq!(
            state.deployments[&1].vtime,
            1.0,
            "vtime must roll back by returned/budget — charged only for the task run"
        );
        assert_eq!(state.deployments[&1].queue.len(), 3);
        assert_eq!(state.give_backs, 1);
        assert_eq!(state.given_back_tasks, 3);
        state.finish(1);
        // The returned tasks are re-claimable and every task runs exactly
        // once overall.
        while let Some((tag, tasks)) = state.claim_many(8, 1) {
            for t in tasks {
                t();
            }
            state.finish(tag);
        }
        assert_eq!(runs.load(Ordering::SeqCst), 4);
        assert_eq!(state.deployments[&1].vtime, 4.0, "full service restores the full charge");
        // Fractional budgets too: budget 2 charges/refunds in halves.
        state.register(2, "half", 2);
        state.enqueue(2, (0..4).map(|_| mk(&runs)).collect());
        let (_, mut tasks) = state.claim_many(8, 1).expect("tag 2 has lower vtime");
        assert_eq!(tasks.len(), 4);
        assert_eq!(state.deployments[&2].vtime, 2.0);
        (tasks.remove(0))();
        assert_eq!(state.give_back(2, tasks), 3);
        assert_eq!(state.deployments[&2].vtime, 0.5);
        // Giving back to a closed deployment drops the tasks (reaped like
        // close's own queue discard) and counts nothing.
        let before = (state.give_backs, state.given_back_tasks);
        state.close(2);
        assert_eq!(state.give_back(2, vec![mk(&runs)]), 0);
        assert_eq!((state.give_backs, state.given_back_tasks), before);
    }

    /// Regression (ISSUE 9 satellite, ROADMAP's systematic straggler): one
    /// worker batch-claims a flush whose first chunk is slow — think a
    /// LITTLE-pinned worker holding big-weighted chunks — and without
    /// give-back the k−1 fast chunks serialize behind it. With the
    /// deadline at zero the unstarted tail must come back for the other
    /// worker, visible in `claim_stats()` give-back counters, and every
    /// task still runs exactly once.
    #[test]
    fn straggler_batch_claim_gives_back_unstarted_tail() {
        let topo = CoreTopology::synthetic_big_little(1, 1, 3.0);
        let pool = SharedPool::with_config(
            PoolConfig::new(2)
                .topology(topo)
                .claim_limit(8)
                .give_back_after(Some(Duration::ZERO)),
        );
        let client = SharedPool::register(&pool, "flush", 2);
        // Occupy one worker so a single worker batch-claims the flush.
        let gate = Arc::new(AtomicBool::new(false));
        {
            let gate = gate.clone();
            client.spawn(vec![Box::new(move || {
                while !gate.load(Ordering::Acquire) {
                    std::thread::sleep(Duration::from_millis(1));
                }
            }) as Task]);
        }
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while pool.shared.state.lock().unwrap().deployments.values().all(|d| d.active == 0) {
            assert!(std::time::Instant::now() < deadline, "blocker never claimed");
            std::thread::sleep(Duration::from_millis(1));
        }
        // A flush whose head chunk is the straggler: deep enough that the
        // free worker's claim takes several chunks (cap ⌈8/2⌉ = 4).
        let done = Arc::new(AtomicU64::new(0));
        let tasks: Vec<Task> = (0..8)
            .map(|i| {
                let done = done.clone();
                Box::new(move || {
                    if i == 0 {
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    done.fetch_add(1, Ordering::SeqCst);
                }) as Task
            })
            .collect();
        client.spawn(tasks);
        // Free the gated worker so it can pick up the returned tail.
        gate.store(true, Ordering::Release);
        while done.load(Ordering::SeqCst) < 8 {
            assert!(std::time::Instant::now() < deadline, "flush never drained");
            std::thread::sleep(Duration::from_millis(1));
        }
        // Wait for idle so in-flight double-executions (there must be
        // none) would have landed before the exactly-once check.
        while pool.stats().deployments.iter().any(|d| d.active > 0) {
            assert!(std::time::Instant::now() < deadline, "workers never went idle");
            std::thread::sleep(Duration::from_millis(1));
        }
        let cs = pool.claim_stats();
        assert!(cs.give_backs >= 1, "straggler claim never gave back: {cs:?}");
        assert!(cs.given_back_tasks >= 1, "no tasks returned: {cs:?}");
        assert_eq!(done.load(Ordering::SeqCst), 8, "a returned task was lost or re-run");
    }

    /// Exhaustive interleaving checks over the production [`PoolState`]
    /// state machine (claim / steal / finish / enqueue / close), driven by
    /// [`crate::testing::sched::explore`]. Every transition here is
    /// executed under the pool mutex in production, so one method call is
    /// exactly one atomic step — a schedule over these steps is a real
    /// thread interleaving. DESIGN.md §9 maps scenarios to coverage.
    mod interleave {
        use super::*;
        use crate::testing::explore;
        use std::sync::atomic::AtomicUsize;

        const THREADS: usize = 2;

        fn mk_task(runs: &Arc<Vec<AtomicUsize>>, id: usize) -> Task {
            let runs = runs.clone();
            Box::new(move || {
                runs[id].fetch_add(1, Ordering::SeqCst);
            })
        }

        fn counters(n: usize) -> Arc<Vec<AtomicUsize>> {
            Arc::new((0..n).map(|_| AtomicUsize::new(0)).collect())
        }

        /// A worker's claim step with the claim-rule invariants asserted
        /// around the production `claim_many`: tier discipline (never steal
        /// while tier-1 work exists), lowest-vtime pick, and vtime advancing
        /// only for the claimed deployment. Claimed tasks execute
        /// immediately — in production they run outside the lock, so their
        /// execution cannot interleave with state transitions anyway.
        fn checked_claim(state: &mut PoolState) -> Option<(u64, usize)> {
            let tier1_min = state
                .deployments
                .values()
                .filter(|d| !d.queue.is_empty() && d.active < d.budget)
                .map(|d| d.vtime)
                .fold(f64::INFINITY, f64::min);
            let steals_before = state.steals;
            let vt_before: BTreeMap<u64, f64> =
                state.deployments.iter().map(|(&t, d)| (t, d.vtime)).collect();
            let claimed = state.claim_many(1, THREADS);
            match &claimed {
                Some((tag, tasks)) => {
                    assert!(!tasks.is_empty(), "a successful claim holds work");
                    if tier1_min.is_finite() {
                        assert_eq!(state.steals, steals_before, "stole past tier-1 work");
                        assert_eq!(vt_before[tag], tier1_min, "picked a non-minimal vtime");
                    } else {
                        assert_eq!(state.steals, steals_before + 1, "uncounted steal");
                    }
                    for (t, d) in &state.deployments {
                        if t == tag {
                            assert!(d.vtime > vt_before[t], "claim must advance vtime");
                        } else {
                            assert_eq!(d.vtime, vt_before[t], "bystander vtime moved");
                        }
                    }
                }
                None => assert_eq!(state.steals, steals_before),
            }
            claimed.map(|(tag, tasks)| {
                let n = tasks.len();
                for t in tasks {
                    t();
                }
                (tag, n)
            })
        }

        #[test]
        fn two_workers_claim_and_steal_every_interleaving() {
            // Two workers over one budget-1 deployment with two tasks: the
            // second claim is a tier-2 steal whenever it lands before the
            // first finish. Every schedule must run both tasks exactly once
            // and return the deployment to idle.
            let n = explore(&[2, 2], usize::MAX, |sched| {
                let runs = counters(2);
                let mut state = PoolState::default();
                state.register(1, "a", 1);
                state.enqueue(1, vec![mk_task(&runs, 0), mk_task(&runs, 1)]);
                let mut held: [Option<u64>; 2] = [None, None];
                let mut step = [0usize; 2];
                for &w in sched {
                    if step[w] == 0 {
                        held[w] = checked_claim(&mut state).map(|(tag, _)| tag);
                    } else if let Some(tag) = held[w].take() {
                        state.finish(tag);
                    }
                    step[w] += 1;
                }
                for r in runs.iter() {
                    assert_eq!(r.load(Ordering::SeqCst), 1, "task lost or re-run: {sched:?}");
                }
                let d = &state.deployments[&1];
                assert_eq!(d.active, 0);
                assert!(d.queue.is_empty());
            });
            assert_eq!(n, 6, "C(4,2) merges of two 2-step workers");
        }

        #[test]
        fn close_interleavings_never_run_doomed_tasks_and_reap_the_entry() {
            // Two workers × a client dropping mid-flight. Tasks claimed
            // before the close run exactly once; tasks still queued at the
            // close never run; the deployment entry is reaped by whichever
            // of close/last-finish comes last.
            const NTASKS: usize = 3;
            let n = explore(&[2, 2, 1], usize::MAX, |sched| {
                let runs = counters(NTASKS);
                let mut state = PoolState::default();
                state.register(7, "doomed", 2);
                state.enqueue(7, (0..NTASKS).map(|i| mk_task(&runs, i)).collect());
                let mut held: [Option<u64>; 2] = [None, None];
                let mut step = [0usize; 2];
                let mut closed = false;
                let mut claimed_before_close = 0usize;
                for &a in sched {
                    if a < 2 {
                        if step[a] == 0 {
                            if let Some((tag, k)) = checked_claim(&mut state) {
                                if !closed {
                                    claimed_before_close += k;
                                }
                                held[a] = Some(tag);
                            }
                        } else if let Some(tag) = held[a].take() {
                            state.finish(tag);
                        }
                        step[a] += 1;
                    } else {
                        state.close(7);
                        closed = true;
                    }
                }
                let total: usize = runs.iter().map(|r| r.load(Ordering::SeqCst)).sum();
                assert_eq!(total, claimed_before_close, "doomed task ran: {sched:?}");
                for r in runs.iter() {
                    assert!(r.load(Ordering::SeqCst) <= 1, "task re-ran: {sched:?}");
                }
                assert!(state.deployments.is_empty(), "closed entry not reaped: {sched:?}");
            });
            assert_eq!(n, 30, "5!/(2!·2!) merges of 2+2+1 steps");
        }

        #[test]
        fn enqueue_catchup_holds_in_every_interleaving() {
            // A backlogged deployment (1) races an idle one (2) whose
            // client enqueues mid-schedule: wherever the enqueue lands, the
            // idle deployment's vtime must come out at or above the floor
            // of the then-backlogged vtimes (no stale-low vtime
            // monopolizing freed workers), and claims keep picking the
            // minimum-vtime contender.
            let n = explore(&[2, 2, 1], usize::MAX, |sched| {
                let runs = counters(3);
                let mut state = PoolState::default();
                state.register(1, "busy", 1);
                state.register(2, "idle", 1);
                state.enqueue(1, vec![mk_task(&runs, 0), mk_task(&runs, 1)]);
                let mut held: [Option<u64>; 2] = [None, None];
                let mut step = [0usize; 2];
                for &a in sched {
                    if a < 2 {
                        if step[a] == 0 {
                            held[a] = checked_claim(&mut state).map(|(tag, _)| tag);
                        } else if let Some(tag) = held[a].take() {
                            state.finish(tag);
                        }
                        step[a] += 1;
                    } else {
                        let floor = state
                            .deployments
                            .values()
                            .filter(|d| !d.queue.is_empty() || d.active > 0)
                            .map(|d| d.vtime)
                            .fold(f64::INFINITY, f64::min);
                        state.enqueue(2, vec![mk_task(&runs, 2)]);
                        if floor.is_finite() {
                            let v = state.deployments[&2].vtime;
                            assert!(v >= floor, "stale-low vtime after catch-up: {sched:?}");
                        }
                    }
                }
            });
            assert_eq!(n, 30);
        }

        /// ISSUE 9 satellite: a give-back racing a concurrent claim. Worker
        /// A batch-claims both tasks of a budget-1 deployment, runs the
        /// first and gives the second back; worker B's claim lands at every
        /// merge point. While A is still active the deployment is
        /// budget-exhausted, so B can reach the returned task only through
        /// a tier-2 steal — `checked_claim` asserts the tier discipline and
        /// steal counting at each position. The returned task runs exactly
        /// once in every schedule, never twice, never zero.
        #[test]
        fn give_back_races_concurrent_steal_every_interleaving() {
            let n = explore(&[3, 2], usize::MAX, |sched| {
                let runs = counters(2);
                let mut state = PoolState::default();
                state.register(1, "giver", 1);
                state.enqueue(1, vec![mk_task(&runs, 0), mk_task(&runs, 1)]);
                let mut a_held: Vec<Task> = Vec::new();
                let mut a_tag: Option<u64> = None;
                let mut b_tag: Option<u64> = None;
                let mut a_step = 0usize;
                let mut b_step = 0usize;
                for &w in sched {
                    if w == 0 {
                        match a_step {
                            0 => {
                                // threads=1 lifts the depth cap, so an
                                // uncontended claim takes the whole queue.
                                if let Some((tag, mut tasks)) = state.claim_many(4, 1) {
                                    (tasks.remove(0))();
                                    a_held = tasks;
                                    a_tag = Some(tag);
                                }
                            }
                            1 => {
                                let gb = std::mem::take(&mut a_held);
                                let expect = gb.len();
                                let vt = state.deployments.get(&1).map(|d| d.vtime);
                                let returned = state.give_back(1, gb);
                                assert_eq!(returned, expect, "open entry refused the tail");
                                if expect > 0 {
                                    let want = vt.unwrap() - expect as f64;
                                    assert_eq!(
                                        state.deployments[&1].vtime,
                                        want,
                                        "rollback != returned/budget: {sched:?}"
                                    );
                                }
                            }
                            _ => {
                                if let Some(tag) = a_tag.take() {
                                    state.finish(tag);
                                }
                            }
                        }
                        a_step += 1;
                    } else if b_step == 0 {
                        b_tag = checked_claim(&mut state).map(|(tag, _)| tag);
                        b_step += 1;
                    } else if let Some(tag) = b_tag.take() {
                        state.finish(tag);
                    }
                }
                // Drain so the exactly-once check covers the returned task
                // in schedules where B's claim came up empty.
                while let Some((tag, tasks)) = state.claim_many(4, 1) {
                    for t in tasks {
                        t();
                    }
                    state.finish(tag);
                }
                for r in runs.iter() {
                    assert_eq!(r.load(Ordering::SeqCst), 1, "task lost or re-run: {sched:?}");
                }
            });
            assert_eq!(n, 10, "C(5,2) merges of a 3-step giver and a 2-step claimer");
        }

        /// ISSUE 9 satellite: a give-back racing the client's close. The
        /// returned task must be reaped **exactly once** — discarded by
        /// `close`'s queue clear or refused by `give_back`'s closed check,
        /// never executed, never leaked — and the deployment entry is
        /// reaped by whichever of close/last-finish comes last.
        #[test]
        fn give_back_races_close_tail_reaped_exactly_once() {
            let n = explore(&[3, 1], usize::MAX, |sched| {
                let runs = counters(2);
                let mut state = PoolState::default();
                state.register(5, "doomed", 1);
                state.enqueue(5, vec![mk_task(&runs, 0), mk_task(&runs, 1)]);
                let mut held: Vec<Task> = Vec::new();
                let mut claimed_first = false;
                let mut a_step = 0usize;
                for &w in sched {
                    if w == 0 {
                        match a_step {
                            0 => {
                                // May come up empty if the close won the
                                // race and discarded the queue.
                                if let Some((_, mut tasks)) = state.claim_many(4, 1) {
                                    assert_eq!(tasks.len(), 2);
                                    (tasks.remove(0))();
                                    claimed_first = true;
                                    held = tasks;
                                }
                            }
                            1 => {
                                let closed =
                                    state.deployments.get(&5).map_or(true, |d| d.closed);
                                let expect = if closed { 0 } else { held.len() };
                                let returned = state.give_back(5, std::mem::take(&mut held));
                                assert_eq!(returned, expect, "{sched:?}");
                            }
                            _ => state.finish(5),
                        }
                        a_step += 1;
                    } else {
                        state.close(5);
                    }
                }
                assert_eq!(runs[0].load(Ordering::SeqCst), usize::from(claimed_first));
                assert_eq!(
                    runs[1].load(Ordering::SeqCst),
                    0,
                    "doomed returned task ran: {sched:?}"
                );
                assert!(state.deployments.is_empty(), "entry not reaped: {sched:?}");
            });
            assert_eq!(n, 4, "4 positions for the close among the giver's 3 steps");
        }

        /// ISSUE 9 satellite: vtime rollback keeps the weighted-fair
        /// accounting consistent in **every** interleaving with a second
        /// deployment enqueueing and claiming concurrently. Invariant at
        /// every step: each deployment's vtime equals its catch-up offset
        /// plus tasks charged minus tasks returned (budgets are 1, so all
        /// quantities are exact integers).
        #[test]
        fn vtime_rollback_fairness_in_every_interleaving() {
            let n = explore(&[3, 2], usize::MAX, |sched| {
                let runs = counters(4);
                let mut state = PoolState::default();
                state.register(1, "giver", 1);
                state.register(2, "other", 1);
                state.enqueue(1, vec![mk_task(&runs, 0), mk_task(&runs, 1), mk_task(&runs, 2)]);
                let mut charged: BTreeMap<u64, f64> = BTreeMap::new();
                charged.insert(1, 0.0);
                charged.insert(2, 0.0);
                let check = |state: &PoolState, charged: &BTreeMap<u64, f64>, sched: &[usize]| {
                    for (t, d) in &state.deployments {
                        assert_eq!(
                            d.vtime, charged[t],
                            "deployment {t} vtime != net service charge: {sched:?}"
                        );
                    }
                };
                let mut a_held: Vec<Task> = Vec::new();
                let mut a_tag: Option<u64> = None;
                let mut b_tag: Option<u64> = None;
                let mut a_step = 0usize;
                let mut b_step = 0usize;
                for &w in sched {
                    if w == 0 {
                        match a_step {
                            0 => {
                                if let Some((tag, mut tasks)) = state.claim_many(8, 1) {
                                    *charged.get_mut(&tag).unwrap() += tasks.len() as f64;
                                    (tasks.remove(0))();
                                    a_held = tasks;
                                    a_tag = Some(tag);
                                }
                            }
                            1 => {
                                if let Some(tag) = a_tag {
                                    let gb = std::mem::take(&mut a_held);
                                    let returned = state.give_back(tag, gb);
                                    *charged.get_mut(&tag).unwrap() -= returned as f64;
                                }
                            }
                            _ => {
                                if let Some(tag) = a_tag.take() {
                                    state.finish(tag);
                                }
                            }
                        }
                        a_step += 1;
                    } else if b_step == 0 {
                        state.enqueue(2, vec![mk_task(&runs, 3)]);
                        // Catch-up at enqueue is a legitimate charge-free
                        // vtime raise — fold it into the expected offset.
                        charged.insert(2, state.deployments[&2].vtime);
                        if let Some((tag, k)) = checked_claim(&mut state) {
                            *charged.get_mut(&tag).unwrap() += k as f64;
                            b_tag = Some(tag);
                        }
                        b_step += 1;
                    } else if let Some(tag) = b_tag.take() {
                        state.finish(tag);
                    }
                    check(&state, &charged, sched);
                }
                // Drain: the rolled-back deployment keeps claiming under
                // the same invariant until every task has run exactly once.
                while let Some((tag, tasks)) = state.claim_many(8, 1) {
                    *charged.get_mut(&tag).unwrap() += tasks.len() as f64;
                    for t in tasks {
                        t();
                    }
                    state.finish(tag);
                    check(&state, &charged, sched);
                }
                for r in runs.iter() {
                    assert_eq!(r.load(Ordering::SeqCst), 1, "task lost or re-run: {sched:?}");
                }
            });
            assert_eq!(n, 10);
        }

        #[test]
        fn deeper_schedules_with_bounded_preemptions() {
            // Two workers × two claim/finish cycles each × a mid-flight
            // close, bounded to 3 preemptions (the CHESS insight: almost
            // all schedule-sensitive bugs need very few). Same invariants
            // as the exhaustive close scenario, an order of magnitude more
            // steps.
            const NTASKS: usize = 4;
            let mut schedules = 0usize;
            explore(&[4, 4, 1], 3, |sched| {
                schedules += 1;
                let runs = counters(NTASKS);
                let mut state = PoolState::default();
                state.register(9, "deep", 2);
                state.enqueue(9, (0..NTASKS).map(|i| mk_task(&runs, i)).collect());
                let mut held: [Option<u64>; 2] = [None, None];
                let mut step = [0usize; 2];
                let mut closed = false;
                let mut claimed_before_close = 0usize;
                for &a in sched {
                    if a < 2 {
                        if step[a] % 2 == 0 {
                            if let Some((tag, k)) = checked_claim(&mut state) {
                                if !closed {
                                    claimed_before_close += k;
                                }
                                held[a] = Some(tag);
                            }
                        } else if let Some(tag) = held[a].take() {
                            state.finish(tag);
                        }
                        step[a] += 1;
                    } else {
                        state.close(9);
                        closed = true;
                    }
                }
                let total: usize = runs.iter().map(|r| r.load(Ordering::SeqCst)).sum();
                assert_eq!(total, claimed_before_close, "doomed task ran: {sched:?}");
                assert!(state.deployments.is_empty(), "entry not reaped: {sched:?}");
            });
            let sequential = explore(&[4, 4, 1], 0, |_| {});
            assert!(schedules > sequential, "preemption bound added no coverage");
        }
    }
}
