//! A from-scratch, std-only work-stealing worker pool.
//!
//! `rayon`/`crossbeam` are unavailable offline, so this implements the small
//! core the execution layer needs: N persistent workers, one deque per
//! worker, LIFO pop of local work and FIFO steal of remote work (the classic
//! locality/fairness split), and a blocking `run` that submits a job's tasks
//! and waits for all of them.
//!
//! Design notes:
//!
//! * Deques are `Mutex<VecDeque>` rather than a lock-free Chase–Lev deque.
//!   Tasks here are *shards* — tens of microseconds to milliseconds of tree
//!   traversal — so a ~20 ns lock is noise; in exchange the pool is obviously
//!   correct and fully safe code.
//! * A submitted task is first *reserved* via the `pending` counter (under
//!   the condvar mutex), then claimed from a deque. Tasks are pushed to a
//!   deque **before** `pending` is incremented, so a worker that wins a
//!   reservation always finds a task; no lost-wakeup window exists.
//! * Panics in tasks are caught so a poisoned shard cannot deadlock the
//!   submitting thread; `run` re-panics after the whole job has drained.

use std::collections::VecDeque;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// A unit of work submitted to the pool.
pub type Task = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    /// One deque per worker; `run` distributes a job's tasks round-robin.
    queues: Vec<Mutex<VecDeque<Task>>>,
    /// Count of submitted-but-unclaimed tasks, guarded by the wakeup mutex.
    pending: Mutex<usize>,
    wakeup: Condvar,
    shutdown: AtomicBool,
    /// Round-robin submission cursor (so consecutive jobs start on
    /// different workers).
    cursor: AtomicUsize,
}

impl Shared {
    /// Pop from our own deque (LIFO: newest first, best locality).
    fn pop_local(&self, w: usize) -> Option<Task> {
        self.queues[w].lock().unwrap().pop_back()
    }

    /// Steal from another worker's deque (FIFO: oldest first, biggest
    /// remaining work under the planner's size-ordered submission).
    fn steal(&self, w: usize) -> Option<Task> {
        let n = self.queues.len();
        for i in 1..n {
            if let Some(t) = self.queues[(w + i) % n].lock().unwrap().pop_front() {
                return Some(t);
            }
        }
        None
    }
}

fn worker_loop(shared: Arc<Shared>, w: usize) {
    loop {
        // Reserve one task (or sleep until one exists / shutdown).
        {
            let mut pending = shared.pending.lock().unwrap();
            loop {
                if *pending > 0 {
                    *pending -= 1;
                    break;
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    return;
                }
                pending = shared.wakeup.wait(pending).unwrap();
            }
        }
        // A reservation guarantees a task exists somewhere; tasks are pushed
        // before `pending` is incremented, so this loop terminates
        // immediately in practice.
        let task = loop {
            if let Some(t) = shared.pop_local(w) {
                break t;
            }
            if let Some(t) = shared.steal(w) {
                break t;
            }
            std::hint::spin_loop();
        };
        task();
    }
}

/// Completion latch for one submitted job.
struct Latch {
    state: Mutex<LatchState>,
    done: Condvar,
}

struct LatchState {
    remaining: usize,
    panicked: bool,
}

impl Latch {
    fn new(n: usize) -> Latch {
        Latch {
            state: Mutex::new(LatchState { remaining: n, panicked: false }),
            done: Condvar::new(),
        }
    }

    fn complete(&self, panicked: bool) {
        let mut s = self.state.lock().unwrap();
        s.remaining -= 1;
        s.panicked |= panicked;
        if s.remaining == 0 {
            self.done.notify_all();
        }
    }

    /// Wait for the whole job; report whether any task panicked.
    fn wait(&self) -> bool {
        let mut s = self.state.lock().unwrap();
        while s.remaining > 0 {
            s = self.done.wait(s).unwrap();
        }
        s.panicked
    }
}

/// A persistent pool of work-stealing workers.
///
/// Workers are *additional* threads: a pool with budget T runs T workers and
/// the thread calling [`WorkerPool::run`] blocks (it does not execute
/// tasks), so T is the engine's compute parallelism.
pub struct WorkerPool {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn a pool with `threads` workers (min 1).
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queues: (0..threads).map(|_| Mutex::new(VecDeque::new())).collect(),
            pending: Mutex::new(0),
            wakeup: Condvar::new(),
            shutdown: AtomicBool::new(false),
            cursor: AtomicUsize::new(0),
        });
        let workers = (0..threads)
            .map(|w| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("exec-worker-{w}"))
                    .spawn(move || worker_loop(shared, w))
                    .expect("spawn exec worker")
            })
            .collect();
        WorkerPool { shared, workers }
    }

    /// Number of workers.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Run a job: execute every task on the pool, blocking until all have
    /// finished. Panics (after the job has fully drained) if any task
    /// panicked. Concurrent `run` calls from different threads are safe;
    /// their tasks interleave in the deques.
    pub fn run(&self, tasks: Vec<Task>) {
        let n = tasks.len();
        if n == 0 {
            return;
        }
        let latch = Arc::new(Latch::new(n));
        let start = self.shared.cursor.fetch_add(n, Ordering::Relaxed);
        for (i, task) in tasks.into_iter().enumerate() {
            let latch = latch.clone();
            let wrapped: Task = Box::new(move || {
                let result = panic::catch_unwind(AssertUnwindSafe(task));
                latch.complete(result.is_err());
            });
            let q = (start + i) % self.shared.queues.len();
            self.shared.queues[q].lock().unwrap().push_back(wrapped);
        }
        // Publish the whole job with one increment, after every push, so a
        // reservation always finds a task and the submit path takes the
        // contended pending lock once per job instead of once per task.
        {
            let mut pending = self.shared.pending.lock().unwrap();
            *pending += n;
            self.shared.wakeup.notify_all();
        }
        if latch.wait() {
            panic!("exec worker task panicked");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        // Wake everyone so parked workers observe the flag.
        let _guard = self.shared.pending.lock().unwrap();
        self.shared.wakeup.notify_all();
        drop(_guard);
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_every_task_exactly_once() {
        let pool = WorkerPool::new(4);
        let hits = Arc::new(AtomicU64::new(0));
        let tasks: Vec<Task> = (0..500)
            .map(|i| {
                let hits = hits.clone();
                Box::new(move || {
                    hits.fetch_add(i + 1, Ordering::Relaxed);
                }) as Task
            })
            .collect();
        pool.run(tasks);
        // Sum 1..=500 — each task ran exactly once.
        assert_eq!(hits.load(Ordering::Relaxed), 500 * 501 / 2);
    }

    #[test]
    fn stealing_drains_imbalanced_load() {
        // One long task plus many short ones: with stealing, total wall time
        // is bounded by the long task, and everything completes.
        let pool = WorkerPool::new(4);
        let done = Arc::new(AtomicU64::new(0));
        let mut tasks: Vec<Task> = Vec::new();
        for i in 0..64 {
            let done = done.clone();
            tasks.push(Box::new(move || {
                if i == 0 {
                    std::thread::sleep(std::time::Duration::from_millis(20));
                }
                done.fetch_add(1, Ordering::Relaxed);
            }));
        }
        pool.run(tasks);
        assert_eq!(done.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn sequential_jobs_reuse_workers() {
        let pool = WorkerPool::new(2);
        let hits = Arc::new(AtomicU64::new(0));
        for _ in 0..20 {
            let tasks: Vec<Task> = (0..8)
                .map(|_| {
                    let hits = hits.clone();
                    Box::new(move || {
                        hits.fetch_add(1, Ordering::Relaxed);
                    }) as Task
                })
                .collect();
            pool.run(tasks);
        }
        assert_eq!(hits.load(Ordering::Relaxed), 160);
    }

    #[test]
    fn concurrent_submitters() {
        let pool = Arc::new(WorkerPool::new(4));
        let hits = Arc::new(AtomicU64::new(0));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let pool = pool.clone();
            let hits = hits.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..10 {
                    let tasks: Vec<Task> = (0..16)
                        .map(|_| {
                            let hits = hits.clone();
                            Box::new(move || {
                                hits.fetch_add(1, Ordering::Relaxed);
                            }) as Task
                        })
                        .collect();
                    pool.run(tasks);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(hits.load(Ordering::Relaxed), 4 * 10 * 16);
    }

    #[test]
    fn task_panic_propagates_after_drain() {
        let pool = WorkerPool::new(2);
        let done = Arc::new(AtomicU64::new(0));
        let mut tasks: Vec<Task> = Vec::new();
        for i in 0..16 {
            let done = done.clone();
            tasks.push(Box::new(move || {
                if i == 3 {
                    panic!("boom");
                }
                done.fetch_add(1, Ordering::Relaxed);
            }));
        }
        let result = panic::catch_unwind(AssertUnwindSafe(|| pool.run(tasks)));
        assert!(result.is_err());
        // Every non-panicking task still ran (no abandoned work).
        assert_eq!(done.load(Ordering::Relaxed), 15);
        // The pool survives for the next job.
        let hits = Arc::new(AtomicU64::new(0));
        let h2 = hits.clone();
        pool.run(vec![Box::new(move || {
            h2.fetch_add(1, Ordering::Relaxed);
        })]);
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn single_thread_pool_works() {
        let pool = WorkerPool::new(1);
        let hits = Arc::new(AtomicU64::new(0));
        let h = hits.clone();
        pool.run(vec![Box::new(move || {
            h.fetch_add(7, Ordering::Relaxed);
        })]);
        assert_eq!(hits.load(Ordering::Relaxed), 7);
    }
}
