//! Sharded, work-stealing parallel execution runtime (DESIGN.md system S18).
//!
//! The paper's engines exploit SIMD lanes within one core; every ARM target
//! in its Table 1 is a multi-core — often heterogeneous big.LITTLE — part.
//! This subsystem adds the missing axis: a from-scratch, std-only
//! work-stealing pool — the server-shared [`pool::SharedPool`] with
//! per-deployment thread budgets and weighted-fair stealing, plus the
//! standalone [`pool::WorkerPool`] facade — a shard planner ([`shard`])
//! choosing between lane-aligned **row sharding**, **tree sharding** with
//! deterministic ordered reduction, and a hybrid of both, weighted by core
//! class ([`topology::CoreTopology`]) — and a [`ParallelEngine`] wrapper
//! that implements [`crate::engine::Engine`], so it drops into the
//! coordinator, selector, CLI and bench harness unchanged. The serving
//! path itself no longer needs the wrapper: the coordinator's batcher
//! enqueues shard tasks straight onto its deployment's [`pool::PoolClient`]
//! (see `coordinator::batcher` and DESIGN.md §5).
//!
//! # Load-bearing contracts
//!
//! * **Determinism** — under the default [`ShardPolicy::Exact`] the
//!   parallel engine is **bit-identical** to the serial engine it wraps,
//!   for every batch size and thread count: row chunks start at multiples
//!   of the engine's lane width, so no SIMD block boundary (and no
//!   floating-point operation order) ever changes. Enforced by
//!   `rust/tests/parallel_exact.rs`. [`ShardPolicy::Throughput`]
//!   additionally unlocks tree/hybrid plans for the small-batch ×
//!   large-forest regime at float-tolerance accuracy (run-to-run
//!   deterministic ordered reduction); see `exec::parallel` for the full
//!   statement.
//! * **Budgets and stealing** — a [`pool::PoolClient`]'s budget is its
//!   worker entitlement *under contention*, not a hard cap: under-budget
//!   deployments are served first (weighted-fair by vtime, so service
//!   rates converge to budget ratios), and budget-exhausted deployments
//!   steal only when every under-budget deployment's queue is empty —
//!   i.e. stolen capacity is always some idle deployment's entitlement,
//!   returned the moment it enqueues work.
//! * **Teardown** — dropping a client discards its queued tasks but lets
//!   in-flight tasks finish; serving drains first (see
//!   `coordinator::batcher`), so no accepted request is dropped.
//! * **Adaptivity** (DESIGN.md §7) — the scheduling loop is *measure,
//!   adapt, enforce*: [`topology::CoreTopology`] supplies only the prior.
//!   Workers can be **pinned** to their assigned cluster
//!   ([`pool::PoolConfig::pin`] via [`affinity`]), executed shards report
//!   throughput into [`feedback::Feedback`], and row-plan weights are
//!   re-derived from measurement (every N flushes in the batcher, every N
//!   predicts in [`ParallelEngine`]). Re-planning changes only lane-aligned
//!   chunk *sizes*, so the Exact bit-exactness contract is untouched.
//!   Deep queues are drained with batch claims
//!   ([`pool::PoolConfig::claim_limit`]) that preserve the weighted-fair /
//!   steal semantics above.

pub mod affinity;
pub mod feedback;
pub mod parallel;
pub mod pool;
pub mod shard;
pub mod topology;

pub use feedback::Feedback;
pub use parallel::ParallelEngine;
pub use pool::{
    current_worker_class, worker_threads_spawned, ClaimStats, DeploymentStats, PoolClient,
    PoolConfig, PoolStats, SharedPool, WorkerPool, CLAIM_SIZE_SLOTS, DEFAULT_CLAIM_LIMIT,
    DEFAULT_GIVE_BACK_AFTER,
};
pub use shard::{
    chunk_slot_classes, chunk_weights, plan, tree_shard_bounds, weighted_row_chunks,
    weighted_row_chunks_slotted, ShardPlan, ShardPolicy,
};
pub use topology::{CoreClass, CoreTopology, WorkerAssignment};
