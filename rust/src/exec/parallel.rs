//! [`ParallelEngine`]: any serial [`Engine`] executed across a worker pool.
//!
//! Implements the `Engine` trait itself, so it drops into the coordinator's
//! batcher, the selector, the CLI and the bench harness unchanged.
//!
//! # Determinism contract
//!
//! * **`ShardPolicy::Exact`** (default): output is **bit-identical** to the
//!   wrapped serial engine for every batch size and thread count. Only row
//!   plans are emitted; chunk boundaries are lane-aligned, so each chunk's
//!   SIMD blocking is exactly the serial blocking of those rows, and each
//!   worker writes a disjoint slice of `out`. This holds **across adaptive
//!   re-plans** (below): re-planning changes only the sizes of lane-aligned
//!   chunks, never tree order or accumulation order — property-tested in
//!   `rust/tests/parallel_exact.rs`.
//! * **`ShardPolicy::Throughput`**: tree-sharded and hybrid plans are also
//!   emitted for small-batch × large-forest work. Partial score vectors are
//!   reduced in shard-index order into per-element sums, so a given
//!   `ParallelEngine` instance is run-to-run deterministic regardless of
//!   scheduling — but the f32 re-association can differ from the serial
//!   fold in the last ulp (the i16 engines' integer partials re-associate
//!   exactly; their final f32 descale does not). Use where a float
//!   tolerance applies (benchmarks, serving without bit-exactness SLOs).
//!   To keep that run-to-run promise, **adaptive re-planning is disabled**
//!   on the tree/hybrid path: a weight change could flip the planner
//!   between `Rows` and `Hybrid`, whose f32 results differ in the last
//!   ulp. Tree shards keep their construction-time weights.
//!
//! # Adaptive re-planning (ISSUE 5)
//!
//! Under row sharding the engine closes the plan→measure→re-plan loop:
//! every chunk task reports `(slot, rows, µs)` into an
//! [`crate::exec::feedback::Feedback`], and every
//! [`REPLAN_EVERY_PREDICTS`] calls the weight vector is re-derived from
//! the observed per-slot throughput. Construction-time topology weights
//! are only the *prior* — a mis-described device (or a throttled cluster)
//! is corrected by measurement within a few batches. Disable with
//! [`ParallelEngine::with_adaptive`]`(false)` for fixed-plan experiments.
//!
//! Tree shards are built once at construction: sub-forest `0` keeps the
//! ensemble's base score, later shards get zero base, and all i16 shards
//! share the full forest's quantization scale so partials descale
//! identically.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::engine::{build, Engine, EngineKind, Precision};
use crate::forest::Forest;
use crate::neon::OpTrace;
use crate::quant::{choose_scale, QuantConfig};
use crate::util::Stopwatch;

use super::feedback::Feedback;
use super::pool::{MutPtr, PoolConfig, Task, WorkerPool};
use super::shard::{
    chunk_weights, plan, tree_shard_bounds, weighted_row_chunks_slotted, ShardPlan, ShardPolicy,
};
use super::topology::CoreTopology;

/// Row-plan weights are re-derived from measured shard throughput every
/// this many `predict_batch` calls (when adaptivity is on).
pub const REPLAN_EVERY_PREDICTS: u64 = 8;

/// Send-able raw pointer wrapper for handing disjoint slice ranges to pool
/// tasks (the writable half, [`MutPtr`], is shared with the fused batcher
/// and lives next to `Task` in `exec::pool`). Safety rests on two
/// invariants enforced by the planner: row ranges never overlap, and
/// `WorkerPool::run` does not return until every task has finished (the
/// borrow outlives all uses).
#[derive(Clone, Copy)]
struct ConstPtr(*const f32);
// SAFETY: sending the raw pointer is sound under the planner invariants
// documented above — tasks only read, ranges are disjoint, and the borrow
// outlives every task because `WorkerPool::run` joins before returning.
unsafe impl Send for ConstPtr {}

/// A serial engine executed by a sharded, work-stealing worker pool.
pub struct ParallelEngine {
    inner: Arc<dyn Engine>,
    /// Sub-engines over contiguous tree ranges (empty under `Exact`).
    tree_shards: Vec<Arc<dyn Engine>>,
    pool: Arc<WorkerPool>,
    topo: CoreTopology,
    policy: ShardPolicy,
    threads: usize,
    /// Construction-time per-chunk-slot weights (topo × threads) — the
    /// adaptive prior, and the fixed weights of the tree/hybrid path.
    base_weights: Vec<f64>,
    /// Live row-plan weights: start at `base_weights`, re-derived from
    /// `feedback` when adaptivity is on.
    weights: Mutex<Vec<f64>>,
    feedback: Arc<Feedback>,
    adaptive: bool,
    predicts: AtomicU64,
}

impl ParallelEngine {
    /// Build the serial engine for `(kind, precision, forest)` and wrap it
    /// with a fresh pool of `threads` workers. Under
    /// [`ShardPolicy::Throughput`] the forest is additionally partitioned
    /// into per-shard sub-engines for tree parallelism.
    pub fn from_forest(
        kind: EngineKind,
        precision: Precision,
        forest: &Forest,
        quant: Option<QuantConfig>,
        threads: usize,
        policy: ShardPolicy,
    ) -> anyhow::Result<ParallelEngine> {
        // Tree shards must share one scale with the full forest (see module
        // docs), so under `Throughput` the quant default is resolved *here*
        // and passed to every sub-build (the i16-typed config is only a
        // scale carrier; `build` re-materializes it at the target storage
        // width). Under `Exact` no shards exist and the quant argument
        // passes through untouched — the wrapped engine is then the exact
        // engine `build` would produce serially, including the i8 tier's
        // per-tree-scale upgrade on `None`.
        let quant = if policy == ShardPolicy::Throughput {
            match precision {
                Precision::I16 => Some(quant.unwrap_or_else(|| choose_scale(forest, 1.0))),
                Precision::I8 => Some(quant.unwrap_or_else(|| {
                    QuantConfig::new(crate::quant::choose_scale_i8(forest, 1.0).scale)
                })),
                // Neither float tier quantizes; pass the argument through.
                Precision::F32 | Precision::F32Flint => quant,
            }
        } else {
            quant
        };
        let inner: Arc<dyn Engine> = Arc::from(build(kind, precision, forest, quant)?);
        let threads = threads.max(1);

        let mut tree_shards: Vec<Arc<dyn Engine>> = Vec::new();
        if policy == ShardPolicy::Throughput && forest.n_trees() >= 2 {
            let weights = vec![1.0; threads.min(forest.n_trees())];
            for (s, (a, b)) in tree_shard_bounds(forest.n_trees(), &weights).iter().enumerate() {
                let mut sub = forest.clone();
                sub.trees = forest.trees[*a..*b].to_vec();
                if s > 0 {
                    // Only shard 0 contributes the base score to the sum.
                    sub.base_score = vec![0.0; forest.n_classes];
                }
                tree_shards.push(Arc::from(build(kind, precision, &sub, quant)?));
            }
            if tree_shards.len() < 2 {
                tree_shards.clear();
            }
        }

        Ok(Self::assemble(inner, tree_shards, policy, PoolConfig::new(threads)))
    }

    /// Wrap an already-built engine (row sharding only — the forest is not
    /// available to partition). Always bit-exact.
    pub fn wrap(engine: Arc<dyn Engine>, threads: usize) -> ParallelEngine {
        Self::assemble(engine, Vec::new(), ShardPolicy::Exact, PoolConfig::new(threads))
    }

    /// [`ParallelEngine::wrap`] with an explicit [`PoolConfig`] (topology,
    /// pinning, batch claiming) — spawns exactly one pool, unlike
    /// `wrap(..).with_pool_config(..)` which would build and immediately
    /// discard a default pool.
    pub fn wrap_with(engine: Arc<dyn Engine>, config: PoolConfig) -> ParallelEngine {
        Self::assemble(engine, Vec::new(), ShardPolicy::Exact, config)
    }

    /// Shared constructor tail: derive weights/feedback from the pool
    /// config's topology and spawn the pool.
    fn assemble(
        inner: Arc<dyn Engine>,
        tree_shards: Vec<Arc<dyn Engine>>,
        policy: ShardPolicy,
        config: PoolConfig,
    ) -> ParallelEngine {
        let threads = config.threads.max(1);
        let topo = config.topology.clone();
        let base_weights = chunk_weights(&topo, threads);
        let pool = Arc::new(WorkerPool::with_config(config));
        let feedback = Arc::new(Feedback::for_pool(pool.pool(), threads));
        ParallelEngine {
            inner,
            tree_shards,
            feedback,
            pool,
            topo,
            policy,
            threads,
            weights: Mutex::new(base_weights.clone()),
            base_weights,
            adaptive: true,
            predicts: AtomicU64::new(0),
        }
    }

    /// Replace the core topology used for weighted shard sizing (e.g.
    /// [`CoreTopology::odroid_xu4`] when emulating a big.LITTLE target).
    /// Resets the feedback loop to the new prior — with **slot-fallback
    /// attribution only**, since the kept pool's worker classes are
    /// numbered by the *old* topology; use
    /// [`ParallelEngine::with_pool_config`] to re-place workers and regain
    /// class attribution.
    pub fn with_topology(self, topo: CoreTopology) -> ParallelEngine {
        let base_weights = chunk_weights(&topo, self.threads);
        let feedback = Arc::new(Feedback::new(base_weights.clone()));
        ParallelEngine {
            topo,
            weights: Mutex::new(base_weights.clone()),
            feedback,
            base_weights,
            ..self
        }
    }

    /// Rebuild the worker pool per `config` (topology, pinning, batch
    /// claiming) and re-derive the weight prior from its topology. The
    /// `bench --exp adaptive` grid uses this to flip pinning/claiming on
    /// one engine definition.
    pub fn with_pool_config(self, config: PoolConfig) -> ParallelEngine {
        let threads = config.threads.max(1);
        let topo = config.topology.clone();
        let base_weights = chunk_weights(&topo, threads);
        let pool = Arc::new(WorkerPool::with_config(config));
        let feedback = Arc::new(Feedback::for_pool(pool.pool(), threads));
        ParallelEngine {
            pool,
            topo,
            threads,
            weights: Mutex::new(base_weights.clone()),
            feedback,
            base_weights,
            ..self
        }
    }

    /// Enable/disable adaptive re-planning (default: on; module docs).
    pub fn with_adaptive(mut self, adaptive: bool) -> ParallelEngine {
        self.adaptive = adaptive;
        self
    }

    /// Worker-thread budget.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The core topology shard weights are derived from.
    pub fn topology(&self) -> &CoreTopology {
        &self.topo
    }

    /// The wrapped serial engine.
    pub fn inner(&self) -> &Arc<dyn Engine> {
        &self.inner
    }

    /// The engine's worker pool (pinning / claim diagnostics).
    pub fn pool(&self) -> &Arc<WorkerPool> {
        &self.pool
    }

    /// The feedback loop driving adaptive re-plans (diagnostics: samples
    /// recorded, re-plans performed).
    pub fn feedback(&self) -> &Arc<Feedback> {
        &self.feedback
    }

    /// Current row-plan weights (the adaptive state; equals the topology
    /// prior until the first re-plan).
    pub fn current_weights(&self) -> Vec<f64> {
        self.weights.lock().unwrap().clone()
    }

    /// Row plan execution: each chunk is a serial `predict_batch` over a
    /// disjoint `(x, out)` window. With `record` set (the adaptive path
    /// only — static Throughput row plans pass false so their dense chunk
    /// indices never pollute the slot attribution), each chunk reports its
    /// measured throughput back to the feedback loop.
    fn run_rows(&self, x: &[f32], out: &mut [f32], chunks: &[(usize, usize, usize)], record: bool) {
        let d = self.inner.n_features();
        let c = self.inner.n_classes();
        let xp = ConstPtr(x.as_ptr());
        let op = MutPtr(out.as_mut_ptr());
        let tasks: Vec<Task> = chunks
            .iter()
            .map(|&(a, b, slot)| {
                let engine = self.inner.clone();
                let feedback = (record && self.adaptive).then(|| self.feedback.clone());
                Box::new(move || {
                    // SAFETY: chunks are disjoint, in-bounds row ranges of
                    // x/out, and the caller blocks in `pool.run` until every
                    // task completes.
                    let (xs, os) = unsafe {
                        (
                            std::slice::from_raw_parts(xp.0.add(a * d), (b - a) * d),
                            std::slice::from_raw_parts_mut(op.0.add(a * c), (b - a) * c),
                        )
                    };
                    let t0 = feedback.is_some().then(|| engine.cost_counters()).flatten();
                    let sw = Stopwatch::start();
                    engine.predict_batch(xs, os);
                    if let Some(f) = feedback {
                        f.record(slot, b - a, sw.micros());
                        if let (Some((r0, e0)), Some((r1, e1))) = (t0, engine.cost_counters()) {
                            f.record_trees(e1.saturating_sub(e0), r1.saturating_sub(r0));
                        }
                    }
                }) as Task
            })
            .collect();
        self.pool.run(tasks);
    }

    /// Tree / hybrid plan execution: every (row-chunk × tree-shard) pair
    /// computes a partial into the shard's buffer; partials are then
    /// reduced in shard-index order (deterministic).
    fn run_trees(&self, x: &[f32], out: &mut [f32], row_chunks: &[(usize, usize)]) {
        let d = self.inner.n_features();
        let c = self.inner.n_classes();
        let n = x.len() / d.max(1);
        let n_shards = self.tree_shards.len();
        let mut partials: Vec<Vec<f32>> = (0..n_shards).map(|_| vec![0f32; n * c]).collect();
        let xp = ConstPtr(x.as_ptr());

        let mut tasks: Vec<Task> = Vec::with_capacity(n_shards * row_chunks.len());
        for (s, shard) in self.tree_shards.iter().enumerate() {
            let pp = MutPtr(partials[s].as_mut_ptr());
            for &(a, b) in row_chunks {
                let engine = shard.clone();
                tasks.push(Box::new(move || {
                    // SAFETY: each task owns the disjoint (shard s, rows
                    // a..b) window of `partials`; buffers outlive `run`.
                    let (xs, os) = unsafe {
                        (
                            std::slice::from_raw_parts(xp.0.add(a * d), (b - a) * d),
                            std::slice::from_raw_parts_mut(pp.0.add(a * c), (b - a) * c),
                        )
                    };
                    engine.predict_batch(xs, os);
                }) as Task);
            }
        }
        self.pool.run(tasks);

        // Ordered reduction: out[i] = Σ_s partials[s][i], s ascending.
        out.copy_from_slice(&partials[0]);
        for p in &partials[1..] {
            for (o, &v) in out.iter_mut().zip(p.iter()) {
                *o += v;
            }
        }
    }

    /// The adaptive row path: plan from the live weights, execute, and
    /// periodically fold the measured throughput back into the weights.
    fn run_rows_adaptive(&self, x: &[f32], out: &mut [f32], n: usize) {
        let chunks = {
            let weights = self.weights.lock().unwrap();
            weighted_row_chunks_slotted(n, self.inner.lanes(), &weights)
        };
        if chunks.len() <= 1 {
            self.inner.predict_batch(x, out);
        } else {
            self.run_rows(x, out, &chunks, true);
        }
        if self.adaptive && chunks.len() > 1 {
            let calls = self.predicts.fetch_add(1, Ordering::Relaxed) + 1;
            if calls % REPLAN_EVERY_PREDICTS == 0 {
                *self.weights.lock().unwrap() = self.feedback.replan();
            }
        }
    }
}

impl Engine for ParallelEngine {
    fn name(&self) -> String {
        format!("{}×{}t", self.inner.name(), self.threads)
    }

    fn lanes(&self) -> usize {
        self.inner.lanes()
    }

    fn n_features(&self) -> usize {
        self.inner.n_features()
    }

    fn n_classes(&self) -> usize {
        self.inner.n_classes()
    }

    fn predict_batch(&self, x: &[f32], out: &mut [f32]) {
        let d = self.inner.n_features().max(1);
        let n = x.len() / d;
        if self.threads <= 1 || n == 0 {
            return self.inner.predict_batch(x, out);
        }
        // Without tree shards every plan is a (bit-exact) row plan — the
        // adaptive path. With tree shards (Throughput), plans stay static
        // so repeated calls remain bit-identical (module docs).
        if self.tree_shards.is_empty() {
            return self.run_rows_adaptive(x, out, n);
        }
        match plan(
            n,
            self.inner.lanes(),
            self.tree_shards.len(),
            self.policy,
            &self.base_weights,
            self.threads,
        ) {
            ShardPlan::Serial => self.inner.predict_batch(x, out),
            ShardPlan::Rows(chunks) => {
                // Static row plan: no feedback recording (this path never
                // re-plans, and its chunk indices are not weight slots).
                let slotted: Vec<(usize, usize, usize)> =
                    chunks.iter().enumerate().map(|(i, &(a, b))| (a, b, i)).collect();
                self.run_rows(x, out, &slotted, false)
            }
            ShardPlan::Trees => self.run_trees(x, out, &[(0, n)]),
            ShardPlan::Hybrid(chunks) => self.run_trees(x, out, &chunks),
        }
    }

    /// Operation counts are workload properties, not schedules: the same
    /// ops execute regardless of which worker runs them, so the serial
    /// engine's trace is the parallel engine's trace.
    fn count_ops(&self, x: &[f32]) -> OpTrace {
        self.inner.count_ops(x)
    }

    /// Cost counters live in the wrapped engine: concurrent chunk tasks all
    /// bump the same atomics, so per-chunk deltas may blend across chunks —
    /// fine for the EWMA consumer (`Feedback::record_trees`).
    fn cost_counters(&self) -> Option<(u64, u64)> {
        self.inner.cost_counters()
    }

    fn memory_bytes(&self) -> usize {
        self.inner.memory_bytes()
            + self.tree_shards.iter().map(|s| s.memory_bytes()).sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetId;
    use crate::forest::builder::{train_random_forest, RfParams, TreeParams};

    fn forest(trees: usize) -> (Forest, crate::data::Dataset) {
        let ds = DatasetId::Magic.generate(700, 0xEC);
        let f = train_random_forest(
            &ds.x,
            &ds.labels,
            ds.d,
            ds.n_classes,
            RfParams {
                n_trees: trees,
                tree: TreeParams { max_leaves: 16, min_samples_leaf: 2, mtry: 0 },
                ..Default::default()
            },
        );
        (f, ds)
    }

    #[test]
    fn exact_rows_bit_identical_all_kinds() {
        let (f, ds) = forest(12);
        for kind in EngineKind::ALL {
            for precision in [Precision::F32, Precision::I16] {
                let serial = build(kind, precision, &f, None).unwrap();
                let par = ParallelEngine::from_forest(
                    kind,
                    precision,
                    &f,
                    None,
                    4,
                    ShardPolicy::Exact,
                )
                .unwrap();
                // Includes a non-lane-multiple remainder (n = 101).
                let x = &ds.x[..ds.d * 101];
                assert_eq!(
                    par.predict(x),
                    serial.predict(x),
                    "{} {:?} not bit-exact",
                    kind.short(),
                    precision
                );
            }
        }
    }

    #[test]
    fn throughput_tree_sharding_close_and_deterministic() {
        let (f, ds) = forest(24);
        let serial = build(EngineKind::Rs, Precision::F32, &f, None).unwrap();
        let par = ParallelEngine::from_forest(
            EngineKind::Rs,
            Precision::F32,
            &f,
            None,
            4,
            ShardPolicy::Throughput,
        )
        .unwrap();
        assert!(par.tree_shards.len() >= 2);
        // Small batch → tree/hybrid plan.
        let x = &ds.x[..ds.d * 5];
        let got = par.predict(x);
        crate::testing::assert_close(&got, &serial.predict(x), 1e-5, 1e-5).unwrap();
        // Run-to-run determinism of the ordered reduction — also across
        // what would be adaptive re-plan boundaries (the tree path must
        // stay static; > REPLAN_EVERY_PREDICTS calls).
        for _ in 0..(REPLAN_EVERY_PREDICTS + 3) {
            assert_eq!(par.predict(x), got);
        }
    }

    #[test]
    fn hybrid_plan_matches_reference() {
        // RS has 16 lanes, so 40 rows yields 3 lane-blocks: more than one
        // row chunk but fewer than the chunk slots → the planner emits a
        // Hybrid plan (see shard::plan tests).
        let (f, ds) = forest(16);
        let fwd = f.predict_batch(&ds.x[..ds.d * 40]);
        let par = ParallelEngine::from_forest(
            EngineKind::Rs,
            Precision::F32,
            &f,
            None,
            4,
            ShardPolicy::Throughput,
        )
        .unwrap();
        let got = par.predict(&ds.x[..ds.d * 40]);
        crate::testing::assert_close(&got, &fwd, 1e-4, 1e-4).unwrap();
        // Deterministic across repeated calls.
        assert_eq!(par.predict(&ds.x[..ds.d * 40]), got);
    }

    #[test]
    fn wrap_is_bit_exact_and_named() {
        let (f, ds) = forest(8);
        let serial: Arc<dyn Engine> =
            Arc::from(build(EngineKind::Vqs, Precision::F32, &f, None).unwrap());
        let par = ParallelEngine::wrap(serial.clone(), 3);
        assert_eq!(par.name(), "VQS×3t");
        assert_eq!(par.lanes(), serial.lanes());
        let x = &ds.x[..ds.d * 33];
        assert_eq!(par.predict(x), serial.predict(x));
    }

    #[test]
    fn one_thread_is_serial_passthrough() {
        let (f, ds) = forest(6);
        let serial = build(EngineKind::Naive, Precision::F32, &f, None).unwrap();
        let par = ParallelEngine::from_forest(
            EngineKind::Naive,
            Precision::F32,
            &f,
            None,
            1,
            ShardPolicy::Exact,
        )
        .unwrap();
        assert_eq!(par.predict(&ds.x), serial.predict(&ds.x));
    }

    #[test]
    fn big_little_topology_accepted() {
        let (f, ds) = forest(8);
        let par = ParallelEngine::from_forest(
            EngineKind::Rs,
            Precision::F32,
            &f,
            None,
            4,
            ShardPolicy::Exact,
        )
        .unwrap()
        .with_topology(CoreTopology::odroid_xu4());
        let serial = build(EngineKind::Rs, Precision::F32, &f, None).unwrap();
        let x = &ds.x[..ds.d * 200];
        assert_eq!(par.predict(x), serial.predict(x));
    }

    #[test]
    fn memory_accounts_for_shards() {
        let (f, _) = forest(16);
        let exact = ParallelEngine::from_forest(
            EngineKind::Qs,
            Precision::F32,
            &f,
            None,
            4,
            ShardPolicy::Exact,
        )
        .unwrap();
        let thr = ParallelEngine::from_forest(
            EngineKind::Qs,
            Precision::F32,
            &f,
            None,
            4,
            ShardPolicy::Throughput,
        )
        .unwrap();
        assert!(thr.memory_bytes() > exact.memory_bytes());
    }

    /// The feedback loop actually closes: sharded predicts record samples,
    /// re-plans fire on schedule, results stay bit-exact throughout, and a
    /// deliberately wrong 3:1 prior converges toward the (homogeneous)
    /// host's measured ~1:1.
    #[test]
    fn adaptive_replans_and_stays_exact() {
        let (f, ds) = forest(10);
        let serial = build(EngineKind::Rs, Precision::F32, &f, None).unwrap();
        let par = ParallelEngine::from_forest(
            EngineKind::Rs,
            Precision::F32,
            &f,
            None,
            2,
            ShardPolicy::Exact,
        )
        .unwrap()
        .with_topology(CoreTopology::synthetic_big_little(1, 1, 3.0));
        let x = &ds.x[..ds.d * 256];
        let want = serial.predict(x);
        for _ in 0..(3 * REPLAN_EVERY_PREDICTS) {
            assert_eq!(par.predict(x), want, "re-plan broke Exact bit-exactness");
        }
        assert!(par.feedback().samples() > 0, "no shard samples recorded");
        assert!(par.feedback().replans() >= 2, "re-planning never engaged");
        let w = par.current_weights();
        assert_ne!(w, par.base_weights, "weights never moved off the 3:1 prior");
    }

    /// `with_adaptive(false)` freezes the construction-time plan.
    #[test]
    fn adaptive_off_keeps_prior_weights() {
        let (f, ds) = forest(8);
        let par = ParallelEngine::from_forest(
            EngineKind::Vqs,
            Precision::F32,
            &f,
            None,
            2,
            ShardPolicy::Exact,
        )
        .unwrap()
        .with_adaptive(false);
        let x = &ds.x[..ds.d * 128];
        for _ in 0..(2 * REPLAN_EVERY_PREDICTS) {
            let _ = par.predict(x);
        }
        assert_eq!(par.feedback().samples(), 0);
        assert_eq!(par.feedback().replans(), 0);
        assert_eq!(par.current_weights(), par.base_weights);
    }

    /// Pinned pool config accepted end-to-end and still bit-exact.
    #[test]
    fn pinned_pool_config_is_bit_exact() {
        let (f, ds) = forest(8);
        let serial = build(EngineKind::Rs, Precision::F32, &f, None).unwrap();
        let par = ParallelEngine::from_forest(
            EngineKind::Rs,
            Precision::F32,
            &f,
            None,
            2,
            ShardPolicy::Exact,
        )
        .unwrap()
        .with_pool_config(
            PoolConfig::new(2)
                .topology(CoreTopology::synthetic_big_little(1, 1, 2.0))
                .pin(true),
        );
        let x = &ds.x[..ds.d * 150];
        assert_eq!(par.predict(x), serial.predict(x));
        assert!(par.pool().pool().pinned_workers() <= 2);
    }
}
