//! Execution feedback: measured per-class shard throughput driving
//! adaptive shard re-planning.
//!
//! PR 1's planner sizes row chunks from *construction-time* topology
//! weights (`relative_speed` guesses per core class). Those weights are
//! wrong whenever the guess is (a mis-described device, a throttled
//! cluster, a co-tenant stealing cycles) — and a static plan stays wrong
//! forever. This module closes the loop: every executed shard task reports
//! `(chunk slot, rows, µs)` — the same wall-clock discipline the selector
//! uses for candidate timing ([`crate::util::Stopwatch`]) — into one
//! [`Feedback`] per deployment/engine, and the planner periodically swaps
//! its weight vector for [`Feedback::replan`]'s (every N flushes in the
//! batcher, every N predicts in [`crate::exec::ParallelEngine`]), so chunk
//! sizes converge to what the workers actually sustain.
//!
//! # Attribution: by executing worker class, slot as fallback
//!
//! A chunk slot is *planned* for a topology class (fastest-first,
//! [`crate::exec::shard::chunk_slot_classes`]), but the work-stealing pool
//! makes no promise about which worker *claims* it — attributing a sample
//! to its plan slot would blend big- and LITTLE-cluster times into every
//! slot and converge a correctly-heterogeneous prior toward uniform.
//! Instead, pool workers publish their own `(pool token, topology class)`
//! in a thread-local ([`crate::exec::pool::current_worker_class`]), and a
//! sample is attributed to the class that **executed** it: with pinning,
//! class throughput is genuinely cluster throughput, so a correct 3:1
//! prior is *confirmed* by measurement rather than eroded, and a wrong
//! prior is corrected. Classes never observed keep their prior weight,
//! rescaled so units agree.
//!
//! Class indices are only comparable within one pool's topology, so a
//! [`Feedback::for_pool`] accepts class samples **only** from workers of
//! that pool (token check) — the wired paths (batcher via
//! `client.pool()`, `ParallelEngine` building pool and feedback from one
//! `PoolConfig`) always match. Everything else — samples from non-worker
//! threads, from a different pool, or a tokenless [`Feedback::new`] (used
//! by `ParallelEngine::with_topology`, which re-seeds weights without
//! re-placing the pool's workers) — falls back to a per-slot EWMA.
//!
//! # Determinism
//!
//! Re-planning changes only the **sizes** of lane-aligned row chunks,
//! never tree order or accumulation order, so `ShardPolicy::Exact` outputs
//! stay bit-identical to serial across re-plan boundaries (property-tested
//! in `rust/tests/parallel_exact.rs`). Weights are validated before
//! adoption: non-finite or non-positive vectors fall back to the
//! construction-time weights.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::pool::{current_worker_class, SharedPool};
use super::shard::{chunk_slot_classes, chunk_weights};

/// EWMA smoothing factor: one observation moves an estimate 25% of the
/// way — fast enough to track a thermal throttle within a few flushes,
/// slow enough that one noisy µs-scale sample cannot whipsaw the plan.
const ALPHA: f64 = 0.25;

/// Floor on a reported duration: µs-scale chunks on a fast host can read
/// as ~0 from a coarse clock; clamping keeps rates finite.
const MIN_MICROS: f64 = 0.05;

struct Slots {
    /// Construction-time weights (the topology prior) — the fallback for
    /// slots with no observations yet, and the shape the live weight
    /// vector must keep.
    base: Vec<f64>,
    /// Topology class each chunk slot is planned for (all zeros when the
    /// topology is unknown, [`Feedback::new`]).
    slot_class: Vec<usize>,
    /// Pool whose worker-class samples are trusted (`None`: slot-only).
    pool_token: Option<u64>,
    /// EWMA throughput (rows/µs) per topology class, attributed by the
    /// executing worker (module docs); `None` until observed.
    class_rate: Vec<Option<f64>>,
    /// Per-slot fallback EWMA for samples without a worker class.
    slot_rate: Vec<Option<f64>>,
    /// EWMA of trees evaluated per row — the early-exit cost signal
    /// ([`Feedback::record_trees`]); `None` until a cost-counting engine
    /// reports. Fixed-cost engines never write it.
    trees_per_row: Option<f64>,
}

/// Per-deployment (or per-engine) feedback accumulator. Cheap to share:
/// one short mutex acquisition per recorded shard, well under the tens of
/// microseconds a shard itself costs.
pub struct Feedback {
    slots: Mutex<Slots>,
    samples: AtomicU64,
    replans: AtomicU64,
}

impl Feedback {
    /// A feedback loop over `base` chunk-slot weights with no pool
    /// binding: every sample lands in the per-slot EWMA. Used where
    /// weights and worker placement are knowingly decoupled
    /// (`ParallelEngine::with_topology`) and by tests.
    pub fn new(base: Vec<f64>) -> Feedback {
        let n = base.len();
        Self::build(base, vec![0; n], None)
    }

    /// The wired constructor: base weights and slot classes derived from
    /// `pool`'s topology × `budget` (mirrors
    /// [`crate::exec::shard::chunk_weights`]), and class samples accepted
    /// only from that pool's workers (token check), so class attribution
    /// always lines up with the topology that numbered the classes.
    pub fn for_pool(pool: &SharedPool, budget: usize) -> Feedback {
        Self::build(
            chunk_weights(pool.topology(), budget),
            chunk_slot_classes(pool.topology(), budget),
            Some(pool.token()),
        )
    }

    fn build(base: Vec<f64>, slot_class: Vec<usize>, pool_token: Option<u64>) -> Feedback {
        let n_slots = base.len();
        let n_classes = slot_class.iter().copied().max().map_or(1, |m| m + 1);
        Feedback {
            slots: Mutex::new(Slots {
                base,
                slot_class,
                pool_token,
                class_rate: vec![None; n_classes],
                slot_rate: vec![None; n_slots],
                trees_per_row: None,
            }),
            samples: AtomicU64::new(0),
            replans: AtomicU64::new(0),
        }
    }

    /// Record one executed shard: chunk slot, rows processed, wall µs.
    /// Attributed to the executing pool worker's topology class when the
    /// worker belongs to the bound pool (module docs), else to the slot;
    /// out-of-range slots (plan shapes can shrink) are ignored.
    pub fn record(&self, slot: usize, rows: usize, micros: f64) {
        if rows == 0 || !micros.is_finite() {
            return;
        }
        let rate = rows as f64 / micros.max(MIN_MICROS);
        let sample = current_worker_class();
        let mut s = self.slots.lock().unwrap();
        let class = match (s.pool_token, sample) {
            (Some(expect), Some((token, c))) if token == expect && c < s.class_rate.len() => {
                Some(c)
            }
            _ => None,
        };
        let cell = match class {
            Some(c) => &mut s.class_rate[c],
            None if slot < s.slot_rate.len() => &mut s.slot_rate[slot],
            None => return,
        };
        *cell = Some(match *cell {
            Some(old) => ALPHA * rate + (1.0 - ALPHA) * old,
            None => rate,
        });
        self.samples.fetch_add(1, Ordering::Relaxed);
    }

    /// Derive a fresh weight vector from the observed throughputs: a slot
    /// weighs its class's EWMA rate (falling back to its own slot EWMA);
    /// unobserved slots keep their base weight, rescaled by the mean
    /// observed rate-per-base-weight so the two unit systems agree — an
    /// unobserved class therefore keeps its *prior ratio* to the observed
    /// ones. Falls back to the base weights entirely when nothing has been
    /// observed or the result would be degenerate (weights must be finite
    /// and positive for the apportionment math).
    pub fn replan(&self) -> Vec<f64> {
        let s = self.slots.lock().unwrap();
        let resolved: Vec<Option<f64>> = (0..s.base.len())
            .map(|i| s.class_rate.get(s.slot_class[i]).copied().flatten().or(s.slot_rate[i]))
            .collect();
        // Mean observed rate per unit of base weight — the exchange rate
        // between "topology weight units" and "measured rows/µs".
        let mut ratio_sum = 0.0;
        let mut ratio_n = 0usize;
        for (i, r) in resolved.iter().enumerate() {
            if let Some(r) = r {
                if s.base[i] > 0.0 {
                    ratio_sum += r / s.base[i];
                    ratio_n += 1;
                }
            }
        }
        if ratio_n == 0 {
            return s.base.clone();
        }
        let exchange = ratio_sum / ratio_n as f64;
        let out: Vec<f64> = resolved
            .iter()
            .enumerate()
            .map(|(i, r)| r.unwrap_or(s.base[i] * exchange))
            .collect();
        if out.iter().any(|w| !w.is_finite() || *w <= 0.0) {
            return s.base.clone();
        }
        self.replans.fetch_add(1, Ordering::Relaxed);
        out
    }

    /// Record the per-task cost an early-exit engine actually paid: `trees`
    /// tree evaluations across `rows` rows for one executed chunk (deltas
    /// of [`crate::engine::Engine::cost_counters`] around the chunk). Keeps
    /// an EWMA of trees/row so adaptive re-planning — and `stats --json`
    /// readers — see the live cost distribution, not the nominal forest
    /// size. Chunks run concurrently, so a delta can blend a neighbour's
    /// trees; that noise is symmetric and the EWMA absorbs it.
    pub fn record_trees(&self, trees: u64, rows: u64) {
        if rows == 0 {
            return;
        }
        let rate = trees as f64 / rows as f64;
        let mut s = self.slots.lock().unwrap();
        s.trees_per_row = Some(match s.trees_per_row {
            Some(old) => ALPHA * rate + (1.0 - ALPHA) * old,
            None => rate,
        });
    }

    /// EWMA trees evaluated per row (`None`: no cost-counting engine has
    /// reported — fixed-cost deployment).
    pub fn trees_per_row(&self) -> Option<f64> {
        self.slots.lock().unwrap().trees_per_row
    }

    /// Shards recorded so far.
    pub fn samples(&self) -> u64 {
        self.samples.load(Ordering::Relaxed)
    }

    /// Current per-class EWMA throughputs (rows/µs; `None` = class never
    /// observed). Introspection only (`stats --json`): the planner keeps
    /// using [`Feedback::replan`].
    pub fn class_rates(&self) -> Vec<Option<f64>> {
        self.slots.lock().unwrap().class_rate.clone()
    }

    /// Successful weight re-derivations so far (diagnostics: proves the
    /// loop is actually closing).
    pub fn replans(&self) -> u64 {
        self.replans.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::pool::{PoolConfig, SharedPool, Task};
    use std::sync::Arc;

    #[test]
    fn unobserved_returns_base() {
        let f = Feedback::new(vec![3.0, 1.0]);
        assert_eq!(f.replan(), vec![3.0, 1.0]);
        assert_eq!(f.replans(), 0, "a base fallback is not a re-plan");
    }

    #[test]
    fn observed_rates_replace_weights() {
        let f = Feedback::new(vec![3.0, 1.0]);
        // The "big" slot actually runs at the same speed as the "little"
        // one — the measured loop must erase the 3:1 prior. (Test threads
        // publish no worker class, so samples land in the slot fallback.)
        for _ in 0..20 {
            f.record(0, 100, 50.0); // 2 rows/µs
            f.record(1, 100, 50.0); // 2 rows/µs
        }
        let w = f.replan();
        assert_eq!(f.replans(), 1);
        assert!((w[0] - w[1]).abs() / w[0] < 0.05, "converged weights {w:?}");
    }

    #[test]
    fn unobserved_slot_keeps_relative_base() {
        let f = Feedback::new(vec![2.0, 1.0]);
        for _ in 0..10 {
            f.record(0, 100, 25.0); // 4 rows/µs on a base-2.0 slot
        }
        let w = f.replan();
        // Slot 1 never reported: its base weight is rescaled by the
        // observed exchange rate (4/2 = 2) so the 2:1 ratio is preserved.
        assert!((w[0] / w[1] - 2.0).abs() < 1e-6, "{w:?}");
    }

    #[test]
    fn ewma_tracks_a_slowdown() {
        let f = Feedback::new(vec![1.0, 1.0]);
        for _ in 0..50 {
            f.record(0, 100, 10.0); // 10 rows/µs
        }
        // Slot 0 throttles to 1 row/µs; within a handful of samples the
        // estimate must drop below half of the old rate.
        for _ in 0..10 {
            f.record(0, 100, 100.0);
        }
        let w = f.replan();
        assert!(w[0] < 5.0, "EWMA stuck at {w:?}");
        assert!(w[0] > 1.0, "EWMA overshot at {w:?}");
    }

    #[test]
    fn degenerate_samples_are_ignored() {
        let f = Feedback::new(vec![1.0, 1.0]);
        f.record(0, 0, 10.0); // no rows
        f.record(0, 10, f64::NAN); // broken clock
        f.record(7, 10, 10.0); // out-of-range slot
        assert_eq!(f.samples(), 0);
        assert_eq!(f.replan(), vec![1.0, 1.0]);
        // A ~zero-duration chunk clamps rather than producing inf.
        f.record(0, 16, 0.0);
        assert!(f.replan().iter().all(|w| w.is_finite() && *w > 0.0));
    }

    /// ISSUE 9: the trees/row cost EWMA — seeded by the first report,
    /// tracking a cost drop (an early-exit engine warming up on easy
    /// traffic), ignoring degenerate zero-row reports.
    #[test]
    fn trees_per_row_ewma_tracks_cost() {
        let f = Feedback::new(vec![1.0]);
        assert_eq!(f.trees_per_row(), None, "fixed-cost engines never report");
        f.record_trees(10, 0); // degenerate: no rows
        assert_eq!(f.trees_per_row(), None);
        f.record_trees(800, 100); // 8 trees/row
        assert_eq!(f.trees_per_row(), Some(8.0));
        for _ in 0..30 {
            f.record_trees(200, 100); // traffic got easy: 2 trees/row
        }
        let t = f.trees_per_row().unwrap();
        assert!((2.0..3.0).contains(&t), "EWMA stuck at {t}");
    }

    /// Class attribution end-to-end: samples recorded *on pool workers*
    /// land in the executing worker's class. Both classes of a synthetic
    /// 1+1 big.LITTLE pool measure the same rate here, so the 3:1 prior
    /// is erased — the homogeneous-host correction the adaptive bench
    /// demonstrates — regardless of which worker claimed which chunk.
    #[test]
    fn class_attribution_from_pool_workers() {
        let topo = crate::exec::CoreTopology::synthetic_big_little(1, 1, 3.0);
        let pool = SharedPool::with_config(PoolConfig::new(2).topology(topo));
        let fb = Arc::new(Feedback::for_pool(&pool, 2));
        let client = SharedPool::register(&pool, "fb", 2);
        // A barrier forces the two tasks onto *different* workers (the
        // depth cap gives single-task claims at queue depth 2 / 2 workers),
        // so both classes observe samples.
        let barrier = Arc::new(std::sync::Barrier::new(2));
        let tasks: Vec<Task> = (0..2)
            .map(|_| {
                let fb = fb.clone();
                let barrier = barrier.clone();
                Box::new(move || {
                    barrier.wait();
                    for _ in 0..10 {
                        fb.record(0, 100, 50.0); // 2 rows/µs on this class
                    }
                }) as Task
            })
            .collect();
        client.run(tasks);
        assert_eq!(fb.samples(), 20);
        let w = fb.replan();
        // Slot layout is [big, big, little, little]; equal measured class
        // rates must produce ~equal weights despite the 3:1 prior.
        assert_eq!(w.len(), 4);
        assert!((w[0] - w[2]).abs() / w[0] < 0.05, "classes not measured: {w:?}");
    }

    /// Token gating: a worker of a *different* pool publishes a class
    /// index that is also valid in this feedback's numbering — the token
    /// mismatch must route its sample to the slot fallback, never into
    /// this topology's class EWMA.
    #[test]
    fn foreign_pool_class_samples_fall_back_to_slots() {
        let topo = crate::exec::CoreTopology::synthetic_big_little(1, 1, 3.0);
        let pool_a = SharedPool::with_config(PoolConfig::new(1).topology(topo));
        let fb = Arc::new(Feedback::for_pool(&pool_a, 2)); // base [3,3,1,1]
        let pool_b = SharedPool::new(1);
        let client_b = SharedPool::register(&pool_b, "b", 1);
        let fbc = fb.clone();
        client_b.run(vec![Box::new(move || {
            for _ in 0..5 {
                fbc.record(2, 100, 50.0); // 2 rows/µs on a LITTLE slot
            }
        }) as Task]);
        let w = fb.replan();
        // The sample landed on slot 2 itself...
        assert!((w[2] - 2.0).abs() < 1e-9, "{w:?}");
        // ... and the big class was never legitimately observed, so its
        // prior ratio to the observed slot is preserved. (If the foreign
        // class-0 sample leaked into pool_a's class 0, w[0] would read
        // 2.0 and the ratio would collapse to 1.)
        assert!((w[0] / w[2] - 3.0).abs() < 1e-6, "class 0 polluted: {w:?}");
    }
}
