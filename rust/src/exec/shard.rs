//! Shard planning: how a `predict_batch` call is split across workers.
//!
//! Two axes of parallelism exist in ensemble inference:
//!
//! * **Row sharding** — split the batch into chunks of instances; each
//!   worker runs the full serial engine on its chunk, writing a disjoint
//!   slice of `out`. Chunk boundaries are multiples of the engine's SIMD
//!   lane width, so every chunk's internal blocking (VQS v=4/8, RS v=16)
//!   is exactly the blocking the serial engine would have used on those
//!   rows: results are **bit-identical** to the serial engine.
//! * **Tree sharding** — partition the forest into sub-forests; workers
//!   compute partial score vectors and an ordered reduction sums them.
//!   The reduction is deterministic (shard-index order, fixed bounds), but
//!   re-associating the f32 leaf-sum fold means results can differ from the
//!   serial engine in the last ulp. See the determinism contract in
//!   `exec::parallel`.
//!
//! Hybrid plans (row × tree) exist for the small-batch × large-forest
//! regime. Chunk sizes are weighted by core class ([`CoreTopology`]) so a
//! big.LITTLE part's fast cores receive proportionally more work; the
//! work-stealing pool then absorbs any residual imbalance.

use super::topology::CoreTopology;

/// Exactness policy for the planner.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardPolicy {
    /// Only bit-exactness-preserving plans (row sharding / serial). The
    /// default everywhere an engine's output is compared against the serial
    /// reference — serving, selection, tests.
    Exact,
    /// Additionally allow tree sharding and hybrid plans. Deterministic per
    /// engine instance, but f32 scores may differ from serial in the last
    /// ulp (integer i16 partials re-associate exactly, their f32 descale
    /// does not).
    Throughput,
}

/// A concrete partition of one `predict_batch` call.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ShardPlan {
    /// Run the serial engine on the calling thread (not enough work to
    /// shard).
    Serial,
    /// Disjoint row ranges `[begin, end)`, lane-aligned except the last.
    Rows(Vec<(usize, usize)>),
    /// Tree-shard indices only (row chunks degenerate to the full batch).
    Trees,
    /// Row chunks × tree shards.
    Hybrid(Vec<(usize, usize)>),
}

/// Split `n` rows into lane-aligned chunks sized proportionally to
/// `weights` (one entry per chunk slot). Chunks are multiples of `lanes`
/// except the last, which absorbs the remainder; empty chunks are dropped.
pub fn weighted_row_chunks(n: usize, lanes: usize, weights: &[f64]) -> Vec<(usize, usize)> {
    weighted_row_chunks_slotted(n, lanes, weights)
        .into_iter()
        .map(|(a, b, _)| (a, b))
        .collect()
}

/// [`weighted_row_chunks`] keeping the **slot attribution**: each chunk is
/// `(begin, end, slot)` where `slot` indexes the weight that sized it. The
/// adaptive planner needs the slot to attribute a measured shard time back
/// to the weight it should correct (`exec::feedback`); empty slots are
/// still dropped, so slots in the output may be sparse.
pub fn weighted_row_chunks_slotted(
    n: usize,
    lanes: usize,
    weights: &[f64],
) -> Vec<(usize, usize, usize)> {
    let lanes = lanes.max(1);
    if n == 0 || weights.is_empty() {
        return Vec::new();
    }
    let blocks = n.div_ceil(lanes);
    let total_w: f64 = weights.iter().sum();
    if total_w <= 0.0 {
        return vec![(0, n, 0)];
    }
    // Largest-remainder apportionment of lane-blocks to chunk slots.
    let mut alloc: Vec<usize> = Vec::with_capacity(weights.len());
    let mut fracs: Vec<(usize, f64)> = Vec::with_capacity(weights.len());
    let mut assigned = 0usize;
    for (i, &w) in weights.iter().enumerate() {
        let exact = blocks as f64 * w / total_w;
        let floor = exact.floor() as usize;
        alloc.push(floor);
        assigned += floor;
        fracs.push((i, exact - floor as f64));
    }
    fracs.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    for &(i, _) in fracs.iter().take(blocks.saturating_sub(assigned)) {
        alloc[i] += 1;
    }
    let mut chunks = Vec::new();
    let mut begin = 0usize;
    for (slot, blocks_here) in alloc.into_iter().enumerate() {
        if blocks_here == 0 || begin >= n {
            continue;
        }
        let end = (begin + blocks_here * lanes).min(n);
        chunks.push((begin, end, slot));
        begin = end;
    }
    // Rounding can leave a tail un-assigned; give it to the last chunk.
    if begin < n {
        if let Some(last) = chunks.last_mut() {
            last.1 = n;
        } else {
            chunks.push((0, n, 0));
        }
    }
    chunks
}

/// Partition `n_trees` into contiguous shards sized proportionally to
/// `weights`, at least one tree per kept shard.
pub fn tree_shard_bounds(n_trees: usize, weights: &[f64]) -> Vec<(usize, usize)> {
    if n_trees == 0 || weights.is_empty() {
        return Vec::new();
    }
    let total_w: f64 = weights.iter().sum();
    if total_w <= 0.0 {
        return vec![(0, n_trees)];
    }
    let mut bounds = Vec::new();
    let mut begin = 0usize;
    let mut acc = 0.0;
    for &w in weights {
        acc += w;
        let end = ((n_trees as f64 * acc / total_w).round() as usize).clamp(begin, n_trees);
        if end > begin {
            bounds.push((begin, end));
            begin = end;
        }
    }
    if begin < n_trees {
        if let Some(last) = bounds.last_mut() {
            last.1 = n_trees;
        } else {
            bounds.push((0, n_trees));
        }
    }
    bounds
}

/// Choose a plan for a batch of `n_rows` against a forest with
/// `n_tree_shards` prepared sub-engines (0 when tree sharding is disabled).
///
/// `weights` has one entry per chunk slot (typically 2× the thread budget
/// for stealing slack, big cores first); `threads` is the actual worker
/// budget, which decides when row parallelism alone saturates the pool.
pub fn plan(
    n_rows: usize,
    lanes: usize,
    n_tree_shards: usize,
    policy: ShardPolicy,
    weights: &[f64],
    threads: usize,
) -> ShardPlan {
    let row_chunks = weighted_row_chunks(n_rows, lanes, weights);
    match policy {
        ShardPolicy::Exact => {
            if row_chunks.len() <= 1 {
                ShardPlan::Serial
            } else {
                ShardPlan::Rows(row_chunks)
            }
        }
        ShardPolicy::Throughput => {
            let threads = threads.max(1);
            if row_chunks.len() >= threads || n_tree_shards < 2 {
                // Enough row parallelism to saturate the workers (or no
                // tree shards available).
                if row_chunks.len() <= 1 {
                    ShardPlan::Serial
                } else {
                    ShardPlan::Rows(row_chunks)
                }
            } else if row_chunks.len() >= 2 {
                ShardPlan::Hybrid(row_chunks)
            } else {
                ShardPlan::Trees
            }
        }
    }
}

/// Convenience: per-chunk weights for a thread budget over a topology, with
/// 2× oversubscription so the stealing pool can rebalance.
pub fn chunk_weights(topo: &CoreTopology, threads: usize) -> Vec<f64> {
    let per_worker = topo.worker_weights(threads);
    let mut w = Vec::with_capacity(per_worker.len() * 2);
    for x in per_worker {
        w.push(x);
        w.push(x);
    }
    w
}

/// Companion to [`chunk_weights`] with identical (2× oversubscribed)
/// layout: the topology **class** each chunk slot's worker assignment
/// belongs to. `exec::feedback` uses it to map measured per-class
/// throughput back onto the slots planned for that class.
pub fn chunk_slot_classes(topo: &CoreTopology, threads: usize) -> Vec<usize> {
    let per_worker = topo.worker_assignments(threads);
    let mut out = Vec::with_capacity(per_worker.len() * 2);
    for a in per_worker {
        out.push(a.class);
        out.push(a.class);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cover(chunks: &[(usize, usize)], n: usize) {
        let mut at = 0;
        for &(a, b) in chunks {
            assert_eq!(a, at, "gap before {a}");
            assert!(b > a);
            at = b;
        }
        assert_eq!(at, n, "chunks must cover 0..{n}");
    }

    #[test]
    fn row_chunks_cover_and_align() {
        for n in [1usize, 7, 16, 33, 100, 1000] {
            for lanes in [1usize, 4, 8, 16] {
                let chunks = weighted_row_chunks(n, lanes, &[1.0; 4]);
                cover(&chunks, n);
                for (i, &(a, b)) in chunks.iter().enumerate() {
                    assert_eq!(a % lanes, 0, "chunk {i} start unaligned");
                    if i + 1 < chunks.len() {
                        assert_eq!(b % lanes, 0, "non-final chunk end unaligned");
                    }
                }
            }
        }
    }

    #[test]
    fn weighted_chunks_favor_heavy_slots() {
        let chunks = weighted_row_chunks(1024, 1, &[3.0, 1.0]);
        cover(&chunks, 1024);
        assert_eq!(chunks.len(), 2);
        let big = chunks[0].1 - chunks[0].0;
        let small = chunks[1].1 - chunks[1].0;
        assert!(big >= 3 * small - 1, "big {big} small {small}");
    }

    #[test]
    fn tiny_batch_degenerates() {
        // Fewer rows than one lane block per slot: a single chunk.
        let chunks = weighted_row_chunks(5, 16, &[1.0; 8]);
        cover(&chunks, 5);
        assert_eq!(chunks.len(), 1);
    }

    #[test]
    fn tree_bounds_cover() {
        for n in [1usize, 2, 7, 64, 257] {
            let b = tree_shard_bounds(n, &[1.0; 4]);
            cover(&b, n);
            assert!(b.len() <= 4.min(n));
        }
    }

    #[test]
    fn plan_exact_never_tree_shards() {
        let w = [1.0; 8]; // 2× oversubscribed slots for a 4-thread budget
        assert_eq!(plan(0, 4, 8, ShardPolicy::Exact, &w, 4), ShardPlan::Serial);
        assert_eq!(plan(3, 4, 8, ShardPolicy::Exact, &w, 4), ShardPlan::Serial);
        match plan(1024, 4, 8, ShardPolicy::Exact, &w, 4) {
            ShardPlan::Rows(chunks) => cover(&chunks, 1024),
            other => panic!("want Rows, got {other:?}"),
        }
    }

    #[test]
    fn plan_throughput_tree_shards_small_batches() {
        let w = [1.0; 8]; // 2× oversubscribed slots for a 4-thread budget
        // Tiny batch, large forest: tree sharding.
        assert_eq!(plan(3, 4, 8, ShardPolicy::Throughput, &w, 4), ShardPlan::Trees);
        // Moderate batch — some row chunks, but fewer than the worker
        // budget: hybrid.
        match plan(8, 4, 8, ShardPolicy::Throughput, &w, 4) {
            ShardPlan::Hybrid(chunks) => cover(&chunks, 8),
            other => panic!("want Hybrid, got {other:?}"),
        }
        // One row chunk per worker already saturates the pool: plain rows,
        // no reduction overhead.
        match plan(16, 4, 8, ShardPolicy::Throughput, &w, 4) {
            ShardPlan::Rows(chunks) => cover(&chunks, 16),
            other => panic!("want Rows, got {other:?}"),
        }
        // Large batch: plain rows.
        match plan(4096, 4, 8, ShardPolicy::Throughput, &w, 4) {
            ShardPlan::Rows(chunks) => cover(&chunks, 4096),
            other => panic!("want Rows, got {other:?}"),
        }
    }

    #[test]
    fn chunk_weights_oversubscribe() {
        let topo = CoreTopology::homogeneous(4);
        assert_eq!(chunk_weights(&topo, 4).len(), 8);
    }

    #[test]
    fn chunk_slot_classes_mirror_weights_layout() {
        let topo = CoreTopology::odroid_xu4();
        let w = chunk_weights(&topo, 8);
        let c = chunk_slot_classes(&topo, 8);
        assert_eq!(w.len(), c.len());
        // Big cluster (class 0) slots first, then LITTLE (class 1).
        assert_eq!(&c[..8], &[0; 8]);
        assert_eq!(&c[8..], &[1; 8]);
        // A slot's weight is its class's weight.
        assert!(w[0] > w[8]);
    }

    #[test]
    fn slotted_chunks_attribute_their_weight() {
        // Slot 1 has weight 0 → dropped; surviving chunks keep their slot
        // index so feedback can credit the right weight entry.
        let chunks = weighted_row_chunks_slotted(64, 4, &[1.0, 0.0, 1.0]);
        let mut at = 0;
        for &(a, b, _) in &chunks {
            assert_eq!(a, at);
            at = b;
        }
        assert_eq!(at, 64);
        let slots: Vec<usize> = chunks.iter().map(|&(_, _, s)| s).collect();
        assert_eq!(slots, vec![0, 2]);
        // The plain variant is exactly the slotted one minus attribution.
        let plain = weighted_row_chunks(64, 4, &[1.0, 0.0, 1.0]);
        assert_eq!(
            plain,
            chunks.iter().map(|&(a, b, _)| (a, b)).collect::<Vec<_>>()
        );
    }
}
