//! Thread→core affinity: actually *enforcing* the placement the shard
//! planner assumes.
//!
//! [`crate::exec::CoreTopology`] weights shard sizes by core class, but a
//! weight only pays off if the worker it was computed for really runs on
//! that class — on a big.LITTLE part an unpinned "big" worker that the
//! kernel schedules onto a LITTLE core inverts the plan (the heaviest shard
//! lands on the slowest core). This module provides the one primitive the
//! pool needs: pin the calling thread to a set of core IDs.
//!
//! # Implementation notes
//!
//! * On Linux this is `sched_setaffinity(0, ...)` through a tiny `unsafe`
//!   `extern "C"` shim — std already links the platform libc, so no new
//!   dependency is introduced (the offline build stays std-only).
//! * Everywhere else (and when the kernel refuses, e.g. a cgroup cpuset
//!   that excludes the requested cores) pinning **degrades to a no-op**:
//!   the worker simply stays migratable and only the shard *weights* apply.
//!   Callers observe the outcome via the `bool` return /
//!   [`crate::exec::SharedPool::pinned_workers`], never an error.
//! * Masks cover CPU IDs `0..1024` (the glibc `cpu_set_t` width); IDs
//!   beyond that are ignored.

/// Number of 64-bit words in a `cpu_set_t`-sized mask (1024 CPUs).
const MASK_WORDS: usize = 16;

#[cfg(target_os = "linux")]
mod sys {
    use super::MASK_WORDS;

    extern "C" {
        // glibc/musl wrappers; pid 0 = the calling thread.
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
        fn sched_getaffinity(pid: i32, cpusetsize: usize, mask: *mut u64) -> i32;
    }

    pub fn set(mask: &[u64; MASK_WORDS]) -> bool {
        // SAFETY: the mask is a valid, initialized cpu_set_t-sized buffer
        // owned by the caller for the duration of the call.
        unsafe { sched_setaffinity(0, std::mem::size_of_val(mask), mask.as_ptr()) == 0 }
    }

    pub fn get() -> Option<[u64; MASK_WORDS]> {
        let mut mask = [0u64; MASK_WORDS];
        // SAFETY: the buffer is writable and correctly sized.
        let ok =
            unsafe { sched_getaffinity(0, std::mem::size_of_val(&mask), mask.as_mut_ptr()) == 0 };
        ok.then_some(mask)
    }
}

/// Whether this platform can pin threads at all (Linux only).
pub fn pinning_supported() -> bool {
    cfg!(target_os = "linux")
}

/// Pin the **calling thread** to the given core IDs. Returns whether the
/// kernel accepted the mask; `false` (empty/out-of-range set, non-Linux
/// platform, or a cpuset that excludes every requested core) means the
/// thread keeps its previous affinity — a graceful no-op, never a panic.
pub fn pin_to_cores(core_ids: &[usize]) -> bool {
    let mut mask = [0u64; MASK_WORDS];
    let mut any = false;
    for &id in core_ids {
        if id < MASK_WORDS * 64 {
            mask[id / 64] |= 1u64 << (id % 64);
            any = true;
        }
    }
    if !any {
        return false;
    }
    pin_mask(&mask)
}

#[cfg(target_os = "linux")]
fn pin_mask(mask: &[u64; MASK_WORDS]) -> bool {
    sys::set(mask)
}

#[cfg(not(target_os = "linux"))]
fn pin_mask(_mask: &[u64; MASK_WORDS]) -> bool {
    false
}

/// The calling thread's current affinity set (core IDs), if the platform
/// exposes one. Used by tests to pick a core that is actually allowed in
/// this cgroup/cpuset, and by diagnostics.
pub fn current_affinity() -> Option<Vec<usize>> {
    current_mask().map(|mask| {
        let mut ids = Vec::new();
        for (w, &bits) in mask.iter().enumerate() {
            for b in 0..64 {
                if bits & (1u64 << b) != 0 {
                    ids.push(w * 64 + b);
                }
            }
        }
        ids
    })
}

#[cfg(target_os = "linux")]
fn current_mask() -> Option<[u64; MASK_WORDS]> {
    sys::get()
}

#[cfg(not(target_os = "linux"))]
fn current_mask() -> Option<[u64; MASK_WORDS]> {
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_set_is_refused() {
        assert!(!pin_to_cores(&[]));
        // Out-of-range IDs are ignored, leaving an empty mask.
        assert!(!pin_to_cores(&[1 << 20]));
    }

    #[test]
    fn pin_to_allowed_core_roundtrips() {
        // Run on a scratch thread so the test harness thread's affinity is
        // never mutated.
        std::thread::spawn(|| {
            let Some(allowed) = current_affinity() else {
                assert!(!pinning_supported(), "linux must expose an affinity set");
                return;
            };
            assert!(!allowed.is_empty());
            let target = allowed[allowed.len() / 2];
            assert!(pin_to_cores(&[target]), "pinning to an allowed core must succeed");
            assert_eq!(current_affinity().unwrap(), vec![target]);
            // Widening back out to the original set also succeeds.
            assert!(pin_to_cores(&allowed));
        })
        .join()
        .unwrap();
    }

    #[test]
    fn supported_matches_platform() {
        assert_eq!(pinning_supported(), cfg!(target_os = "linux"));
    }
}
