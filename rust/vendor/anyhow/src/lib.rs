//! In-tree stand-in for the `anyhow` crate.
//!
//! The build environment has no access to crates.io (see the workspace's
//! DESIGN.md "Substitutions"), so this vendored path dependency implements
//! exactly the slice of `anyhow`'s API the repository uses: [`Error`],
//! [`Result`], the [`Context`] extension trait for `Result`/`Option`, and
//! the `anyhow!` / `bail!` / `ensure!` macros. Error values carry a context
//! chain; `{e}` prints the outermost message, `{e:#}` the full chain joined
//! with `": "` — matching upstream `anyhow` semantics for the formats the
//! code relies on.

use std::error::Error as StdError;
use std::fmt;

/// A dynamically typed error with a context chain (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context/cause messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{e:#}`: the whole chain, `outer: inner: root`.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Flatten the source chain into context strings.
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`: `std::result::Result` with [`Error`] as the default
/// error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file gone")
    }

    #[test]
    fn display_and_chain() {
        let e: Error = Result::<(), _>::Err(io_err()).context("reading model").unwrap_err();
        assert_eq!(e.to_string(), "reading model");
        assert_eq!(format!("{e:#}"), "reading model: file gone");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("--model required").unwrap_err();
        assert_eq!(e.to_string(), "--model required");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(5).unwrap_err().to_string(), "five is right out");
        assert_eq!(f(11).unwrap_err().to_string(), "x too big: 11");
        let e = anyhow!("plain {}", 1);
        assert_eq!(e.to_string(), "plain 1");
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "file gone");
    }
}
