//! Integration + property tests: every engine must agree with the reference
//! traversal on every dataset and forest shape — the repo-level analogue of
//! the paper's "we made sure all implementations produced the same
//! prediction for the same ensemble" (§6).

use arbors::data::DatasetId;
use arbors::engine::{build, variant_name, EngineKind, Precision};
use arbors::forest::builder::{train_random_forest, RfParams, TreeParams};
use arbors::forest::Forest;
use arbors::quant::{QForest, QuantConfig};
use arbors::testing::{assert_close, Runner};
use arbors::util::Pcg32;

fn train(ds: &arbors::data::Dataset, trees: usize, leaves: usize, seed: u64) -> Forest {
    train_random_forest(
        &ds.x,
        &ds.labels,
        ds.d,
        ds.n_classes,
        RfParams {
            n_trees: trees,
            tree: TreeParams { max_leaves: leaves, min_samples_leaf: 2, mtry: 0 },
            seed,
            ..Default::default()
        },
    )
}

#[test]
fn all_engines_agree_on_all_datasets() {
    for id in DatasetId::ALL {
        let ds = id.generate(600, 0xE2E);
        for leaves in [32usize, 64] {
            let f = train(&ds, 10, leaves, 3);
            let cfg = QuantConfig::paper_default();
            let qf = QForest::from_forest(&f, cfg);
            let x = &ds.x[..ds.d * 100];
            let want_f = f.predict_batch(x);
            let want_q = qf.predict_batch(x);
            // Same resolution policy `build(.., I8, None)` applies (global,
            // auto-upgraded to per-tree scales when global widens), so the
            // reference cannot drift from what the engines were built on.
            let qf8 = arbors::quant::quantize_i8_auto(&f, 1.0);
            let want_q8 = qf8.predict_batch(x);
            for (kind, precision) in arbors::engine::all_variants_with_i8() {
                // The i8 tier chooses its own scale (the i16 carrier would
                // saturate 8-bit storage) and covers all five families.
                let quant = match precision {
                    Precision::I16 => Some(cfg),
                    _ => None,
                };
                let e = build(kind, precision, &f, quant)
                    .unwrap_or_else(|e| panic!("{}: {e}", variant_name(kind, precision)));
                let got = e.predict(x);
                match precision {
                    Precision::F32 => {
                        assert_close(&got, &want_f, 1e-4, 1e-4).unwrap_or_else(|msg| {
                            panic!("{} on {} (L={leaves}): {msg}", variant_name(kind, precision), id.name())
                        });
                    }
                    Precision::I16 => {
                        assert_eq!(
                            got,
                            want_q,
                            "{} on {} (L={leaves})",
                            variant_name(kind, precision),
                            id.name()
                        );
                    }
                    Precision::I8 => {
                        assert_eq!(
                            got,
                            want_q8,
                            "{} on {} (L={leaves})",
                            variant_name(kind, precision),
                            id.name()
                        );
                    }
                    // FLInt carrier: bit-identical to the f32 twin engine
                    // by construction (not merely close; the dedicated
                    // property suite is rust/tests/flint_exact.rs).
                    Precision::F32Flint => {
                        let twin = build(kind, Precision::F32, &f, None)
                            .unwrap_or_else(|e| panic!("{} twin: {e}", kind.short()));
                        assert_eq!(
                            got,
                            twin.predict(x),
                            "{} on {} (L={leaves}) diverged from its f32 twin",
                            variant_name(kind, precision),
                            id.name()
                        );
                    }
                }
            }
        }
    }
}

/// Property: on random forests and random inputs, the whole QuickScorer
/// family equals the naive traversal (argmax and scores).
#[test]
fn property_random_forests_random_inputs() {
    Runner::new(24).with_seed(0xF0).run(|rng: &mut Pcg32, size| {
        // Random synthetic problem of random shape.
        let d = rng.range(2, 12);
        let c = rng.range(1, 5).max(1);
        let n = 80 + size;
        let mut x = Vec::with_capacity(n * d);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            for _ in 0..d {
                x.push(rng.f32());
            }
            y.push(rng.below(c) as u32);
        }
        let leaves = *rng.choose(&[4usize, 8, 16, 32, 64]);
        let f = train_random_forest(
            &x,
            &y,
            d,
            c,
            RfParams {
                n_trees: rng.range(1, 10),
                tree: TreeParams { max_leaves: leaves, min_samples_leaf: 1, mtry: 0 },
                seed: rng.next_u64(),
                ..Default::default()
            },
        );
        let want = f.predict_batch(&x[..d * 40]);
        for kind in [EngineKind::Qs, EngineKind::Vqs, EngineKind::Rs, EngineKind::IfElse] {
            let e = build(kind, Precision::F32, &f, None).map_err(|e| e.to_string())?;
            let got = e.predict(&x[..d * 40]);
            assert_close(&got, &want, 1e-4, 1e-4)
                .map_err(|m| format!("{} (L={leaves}): {m}", kind.short()))?;
        }
        Ok(())
    });
}

/// Property: quantized engines are bit-identical to the quantized naive
/// reference under random scales.
#[test]
fn property_quantized_engines_bit_identical() {
    Runner::new(16).with_seed(0xF1).run(|rng: &mut Pcg32, size| {
        let d = rng.range(2, 8);
        let n = 60 + size;
        let mut x = Vec::with_capacity(n * d);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            for _ in 0..d {
                x.push(rng.f32());
            }
            y.push(rng.below(2) as u32);
        }
        let f = train_random_forest(
            &x,
            &y,
            d,
            2,
            RfParams {
                n_trees: rng.range(1, 8),
                tree: TreeParams {
                    max_leaves: *rng.choose(&[8usize, 32, 64]),
                    min_samples_leaf: 1,
                    mtry: 0,
                },
                seed: rng.next_u64(),
                ..Default::default()
            },
        );
        // Random (coarse!) scale exercises real quantization collisions;
        // capped at the overflow-safe bound so the i16 SIMD accumulators of
        // qVQS/qRS cannot wrap (paper §5's scale-selection constraint; the
        // i32-accumulating reference would diverge on wrap).
        let cap = arbors::quant::max_safe_scale(&f, 1.0);
        let cfg: QuantConfig =
            QuantConfig::new(rng.choose(&[64.0f32, 1024.0, 32768.0]).min(cap));
        let qf = QForest::from_forest(&f, cfg);
        let want = qf.predict_batch(&x[..d * 30]);
        for kind in EngineKind::ALL {
            let e = build(kind, Precision::I16, &f, Some(cfg)).map_err(|e| e.to_string())?;
            let got = e.predict(&x[..d * 30]);
            if got != want {
                return Err(format!("{} differs under scale {}", kind.short(), cfg.scale));
            }
        }
        Ok(())
    });
}

/// Ranking forests (C=1, GBT) work through the same engines.
#[test]
fn ranking_forest_engines_agree() {
    use arbors::forest::builder::{train_gbt, GbtParams};
    let ds = arbors::data::ranking::msn_like(20, 15, 5);
    let f = train_gbt(
        &ds.x,
        &ds.relevance,
        ds.d,
        GbtParams {
            n_trees: 30,
            tree: TreeParams { max_leaves: 32, min_samples_leaf: 2, mtry: 24 },
            learning_rate: 0.2,
            ..Default::default()
        },
    );
    let x = &ds.x[..ds.d * 64];
    let want = f.predict_batch(x);
    for kind in EngineKind::ALL {
        let e = build(kind, Precision::F32, &f, None).unwrap();
        assert_close(&e.predict(x), &want, 1e-4, 1e-4)
            .unwrap_or_else(|m| panic!("{}: {m}", kind.short()));
    }
}

/// Engines reject unsupported shapes cleanly instead of mis-scoring.
#[test]
fn oversized_trees_rejected() {
    let ds = DatasetId::Magic.generate(3000, 9);
    let f = train(&ds, 2, 128, 4);
    if f.max_leaves() <= 64 {
        // Training did not reach >64 leaves; nothing to assert.
        return;
    }
    for kind in [EngineKind::Qs, EngineKind::Vqs, EngineKind::Rs] {
        assert!(build(kind, Precision::F32, &f, None).is_err());
    }
    // NA/IE handle any leaf count.
    assert!(build(EngineKind::Naive, Precision::F32, &f, None).is_ok());
    assert!(build(EngineKind::IfElse, Precision::F32, &f, None).is_ok());
}

/// Serialized models predict identically after a round-trip (failure
/// injection: truncated file must error, not crash).
#[test]
fn forest_roundtrip_and_corruption() {
    let ds = DatasetId::Eeg.generate(400, 11);
    let f = train(&ds, 6, 16, 5);
    let dir = std::env::temp_dir().join(format!("arbors_it_{}", std::process::id()));
    let path = dir.join("m.json");
    arbors::forest::io::save(&f, &path).unwrap();
    let f2 = arbors::forest::io::load(&path).unwrap();
    assert_eq!(f, f2);

    // Corrupt the file: loader must return Err.
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::write(&path, &text[..text.len() / 2]).unwrap();
    assert!(arbors::forest::io::load(&path).is_err());
    std::fs::remove_dir_all(&dir).ok();
}
