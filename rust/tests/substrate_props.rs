//! Property tests over the substrate layers: the NEON simulator against
//! scalar reference semantics, and the JSON parser against a round-trip +
//! garbage fuzz.

use arbors::neon::*;
use arbors::testing::Runner;
use arbors::util::{Json, Pcg32};

fn rand_u8x16(rng: &mut Pcg32) -> U8x16 {
    let mut v = [0u8; 16];
    for b in v.iter_mut() {
        *b = rng.next_u32() as u8;
    }
    U8x16(v)
}

#[test]
fn neon_u8_ops_match_scalar() {
    Runner::new(64).with_seed(0x9e09).run(|rng, _| {
        let a = rand_u8x16(rng);
        let b = rand_u8x16(rng);
        let sel = rand_u8x16(rng);
        for lane in 0..16 {
            let (x, y, s) = (a.0[lane], b.0[lane], sel.0[lane]);
            if vandq_u8(a, b).0[lane] != x & y {
                return Err("vandq".into());
            }
            if vorrq_u8(a, b).0[lane] != x | y {
                return Err("vorrq".into());
            }
            if vmvnq_u8(a).0[lane] != !x {
                return Err("vmvnq".into());
            }
            if vbslq_u8(sel, a, b).0[lane] != (s & x) | (!s & y) {
                return Err("vbslq".into());
            }
            if vceqq_u8(a, b).0[lane] != if x == y { 0xFF } else { 0 } {
                return Err("vceqq".into());
            }
            if vtstq_u8(a, b).0[lane] != if x & y != 0 { 0xFF } else { 0 } {
                return Err("vtstq".into());
            }
            if vrbitq_u8(a).0[lane] != x.reverse_bits() {
                return Err("vrbitq".into());
            }
            if vclzq_u8(a).0[lane] != x.leading_zeros() as u8 {
                return Err("vclzq".into());
            }
            if vmlaq_u8(a, b, sel).0[lane] != x.wrapping_add(y.wrapping_mul(s)) {
                return Err("vmlaq".into());
            }
        }
        Ok(())
    });
}

#[test]
fn neon_widening_chain_preserves_masks() {
    // Any u16 mask (all-ones/zero lanes) widened via the §5.1 chain must
    // stay all-ones/zero at every width.
    Runner::new(64).with_seed(0x9e10).run(|rng, _| {
        let mut m = [0u16; 8];
        for lane in m.iter_mut() {
            *lane = if rng.bool(0.5) { u16::MAX } else { 0 };
        }
        let mask = U16x8(m);
        let mi = vreinterpretq_s16_u16(mask);
        let lo = vreinterpretq_u32_s32(vmovl_s16(vget_low_s16(mi)));
        let hi = vreinterpretq_u32_s32(vmovl_s16(vget_high_s16(mi)));
        for lane in 0..4 {
            let want_lo = if m[lane] != 0 { u32::MAX } else { 0 };
            let want_hi = if m[4 + lane] != 0 { u32::MAX } else { 0 };
            if lo.0[lane] != want_lo || hi.0[lane] != want_hi {
                return Err(format!("u32 widen broke mask at lane {lane}"));
            }
        }
        // On to u64.
        let lo64 = vreinterpretq_u64_s64(vmovl_s32(vget_low_s32(i32x4_from_u32(lo))));
        for lane in 0..2 {
            let want = if m[lane] != 0 { u64::MAX } else { 0 };
            if lo64.0[lane] != want {
                return Err(format!("u64 widen broke mask at lane {lane}"));
            }
        }
        Ok(())
    });
}

#[test]
fn neon_f32_compare_matches_scalar_including_nan() {
    Runner::new(64).with_seed(0x9e11).run(|rng, _| {
        let mut a = [0f32; 4];
        let mut b = [0f32; 4];
        for lane in 0..4 {
            a[lane] = match rng.below(5) {
                0 => f32::NAN,
                1 => f32::INFINITY,
                2 => f32::NEG_INFINITY,
                _ => rng.f32() * 2.0 - 1.0,
            };
            b[lane] = if rng.bool(0.3) { a[lane] } else { rng.f32() * 2.0 - 1.0 };
        }
        let m = vcgtq_f32(F32x4(a), F32x4(b));
        for lane in 0..4 {
            let want = if a[lane] > b[lane] { u32::MAX } else { 0 };
            if m.0[lane] != want {
                return Err(format!("lane {lane}: {} > {} mask {:#x}", a[lane], b[lane], m.0[lane]));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// JSON
// ---------------------------------------------------------------------------

fn rand_json(rng: &mut Pcg32, depth: usize) -> Json {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.bool(0.5)),
        2 => {
            // Finite doubles that survive text round-trip exactly.
            Json::Num((rng.next_u32() as i32) as f64 / 8.0)
        }
        3 => {
            let len = rng.below(12);
            let s: String = (0..len)
                .map(|_| {
                    let c = rng.below(96) as u8 + 32;
                    c as char
                })
                .collect();
            Json::Str(s)
        }
        4 => Json::Arr((0..rng.below(5)).map(|_| rand_json(rng, depth - 1)).collect()),
        _ => {
            let mut obj = Json::obj();
            for i in 0..rng.below(5) {
                obj.set(&format!("k{i}"), rand_json(rng, depth - 1));
            }
            obj
        }
    }
}

#[test]
fn json_roundtrip_property() {
    Runner::new(128).with_seed(0x150).run(|rng, _| {
        let v = rand_json(rng, 3);
        let compact = Json::parse(&v.dump()).map_err(|e| e.to_string())?;
        if compact != v {
            return Err(format!("compact roundtrip: {} != {}", compact.dump(), v.dump()));
        }
        let pretty = Json::parse(&v.pretty()).map_err(|e| e.to_string())?;
        if pretty != v {
            return Err("pretty roundtrip".into());
        }
        Ok(())
    });
}

#[test]
fn json_fuzz_never_panics() {
    // Random byte soup: the parser must return Err or Ok, never panic.
    Runner::new(256).with_max_size(64).with_seed(0x151).run(|rng, size| {
        let len = rng.below(size + 2);
        let bytes: Vec<u8> = (0..len).map(|_| (rng.below(128)) as u8).collect();
        if let Ok(text) = std::str::from_utf8(&bytes) {
            let _ = Json::parse(text);
        }
        Ok(())
    });
}

#[test]
fn json_mutation_fuzz() {
    // Take a valid document, flip bytes, and check the parser still never
    // panics and either errors or produces something re-serializable.
    Runner::new(128).with_seed(0x152).run(|rng, _| {
        let v = rand_json(rng, 3);
        let mut text = v.dump().into_bytes();
        if !text.is_empty() {
            for _ in 0..1 + rng.below(3) {
                let i = rng.below(text.len());
                text[i] = rng.below(128) as u8;
            }
        }
        if let Ok(s) = std::str::from_utf8(&text) {
            if let Ok(parsed) = Json::parse(s) {
                let _ = parsed.dump();
            }
        }
        Ok(())
    });
}
