//! The exec runtime's exactness contract, enforced as a property:
//! `ParallelEngine` under its default policy (`ShardPolicy::Exact`) is
//! **bit-identical** to the serial engine for all ten (kind, precision)
//! variants, across random forests, batch sizes (including non-lane-multiple
//! remainders), and 1–8 threads. Lane-aligned row sharding means every
//! worker replays exactly the SIMD blocking the serial engine would have
//! used on its rows — so equality here is `==` on the f32 bits, not a
//! tolerance.

use arbors::engine::{all_variants, build, build_parallel, variant_name};
use arbors::exec::{ParallelEngine, ShardPolicy};
use arbors::forest::builder::{train_random_forest, RfParams, TreeParams};
use arbors::quant::{max_safe_scale, QuantConfig};
use arbors::testing::Runner;
use arbors::util::Pcg32;

#[test]
fn parallel_engine_bit_identical_to_serial() {
    Runner::new(10).with_seed(0xEAC7).run(|rng: &mut Pcg32, size| {
        // Random problem shape.
        let d = rng.range(2, 10);
        let c = rng.range(1, 4).max(1);
        let n_train = 100 + size;
        let mut x = Vec::with_capacity(n_train * d);
        let mut y = Vec::with_capacity(n_train);
        for _ in 0..n_train {
            for _ in 0..d {
                x.push(rng.f32());
            }
            y.push(rng.below(c) as u32);
        }
        let f = train_random_forest(
            &x,
            &y,
            d,
            c,
            RfParams {
                n_trees: rng.range(1, 12),
                tree: TreeParams {
                    max_leaves: *rng.choose(&[4usize, 8, 16, 32, 64]),
                    min_samples_leaf: 1,
                    mtry: 0,
                },
                seed: rng.next_u64(),
                ..Default::default()
            },
        );
        // Overflow-safe shared scale so the i16 engines are well-defined.
        let cap = max_safe_scale(&f, 1.0);
        let cfg: QuantConfig =
            QuantConfig::new(rng.choose(&[256.0f32, 4096.0, 32768.0]).min(cap));

        // Deliberately awkward batch sizes: 1, primes, non-multiples of
        // every lane width (4 / 8 / 16).
        let n_eval = *rng.choose(&[1usize, 3, 17, 33, 50 + size % 23]);
        let xe: Vec<f32> = (0..n_eval * d).map(|_| rng.f32()).collect();

        for (kind, precision) in all_variants() {
            let serial = build(kind, precision, &f, Some(cfg)).map_err(|e| e.to_string())?;
            let want = serial.predict(&xe);
            for threads in [1usize, 2, 3, 4, 8] {
                let par = build_parallel(kind, precision, &f, Some(cfg), threads)
                    .map_err(|e| e.to_string())?;
                let got = par.predict(&xe);
                if got != want {
                    let first = got
                        .iter()
                        .zip(&want)
                        .position(|(a, b)| a != b)
                        .unwrap_or(0);
                    return Err(format!(
                        "{} × {threads}t differs from serial at n={n_eval} \
                         (first mismatch at flat index {first}: {} vs {})",
                        variant_name(kind, precision),
                        got[first],
                        want[first],
                    ));
                }
            }
        }
        Ok(())
    });
}

/// ISSUE 5 acceptance: adaptive re-planning under `ShardPolicy::Exact`
/// stays bit-exact with serial **across re-plan boundaries** — the weight
/// vector is re-derived from measured shard throughput every
/// `REPLAN_EVERY_PREDICTS` calls (seeded here with a deliberately wrong
/// 3:1 big.LITTLE prior so re-plans genuinely move the chunk boundaries),
/// and every call before, at, and after each boundary must equal the
/// serial engine bit-for-bit, for 1–8 threads.
#[test]
fn adaptive_replanning_stays_bit_exact_across_boundaries() {
    use arbors::exec::parallel::REPLAN_EVERY_PREDICTS;
    let mut rng = Pcg32::seeded(0xADA7);
    let d = 6;
    let n = 500;
    let x: Vec<f32> = (0..n * d).map(|_| rng.f32()).collect();
    let y: Vec<u32> = (0..n).map(|_| rng.below(2) as u32).collect();
    let f = train_random_forest(
        &x,
        &y,
        d,
        2,
        RfParams {
            n_trees: 12,
            tree: TreeParams { max_leaves: 16, min_samples_leaf: 2, mtry: 0 },
            ..Default::default()
        },
    );
    // Every variant at a shared overflow-safe scale; deliberately awkward
    // batch (181 rows: prime, remainders at every lane width).
    let cfg: QuantConfig = QuantConfig::new(4096.0f32.min(max_safe_scale(&f, 1.0)));
    let xe = &x[..d * 181];
    for (kind, precision) in all_variants() {
        let serial = build(kind, precision, &f, Some(cfg)).unwrap();
        let want = serial.predict(xe);
        for threads in [1usize, 2, 3, 4, 8] {
            let par = ParallelEngine::from_forest(
                kind,
                precision,
                &f,
                Some(cfg),
                threads,
                ShardPolicy::Exact,
            )
            .unwrap()
            .with_topology(arbors::exec::CoreTopology::synthetic_big_little(
                1,
                threads.saturating_sub(1).max(1),
                3.0,
            ));
            // 2½ re-plan windows: crosses at least two boundaries.
            for call in 0..(2 * REPLAN_EVERY_PREDICTS + REPLAN_EVERY_PREDICTS / 2) {
                assert_eq!(
                    par.predict(xe),
                    want,
                    "{} × {threads}t diverged from serial at call {call} \
                     (adaptive re-plan broke Exact)",
                    variant_name(kind, precision),
                );
            }
            if threads > 1 {
                assert!(
                    par.feedback().samples() > 0 || par.current_weights().len() <= 1,
                    "{} × {threads}t: adaptive loop never observed a shard",
                    variant_name(kind, precision),
                );
            }
        }
    }
}

/// The same engine pipeline through the explicit `ParallelEngine` API with a
/// big.LITTLE topology: weighted (uneven) chunks must not break exactness.
#[test]
fn parallel_engine_exact_under_big_little_weights() {
    let mut rng = Pcg32::seeded(0xB16);
    let d = 8;
    let n = 400;
    let x: Vec<f32> = (0..n * d).map(|_| rng.f32()).collect();
    let y: Vec<u32> = (0..n).map(|_| rng.below(3) as u32).collect();
    let f = train_random_forest(
        &x,
        &y,
        d,
        3,
        RfParams {
            n_trees: 10,
            tree: TreeParams { max_leaves: 32, min_samples_leaf: 2, mtry: 0 },
            ..Default::default()
        },
    );
    for (kind, precision) in all_variants() {
        let serial = build(kind, precision, &f, None).unwrap();
        let par = ParallelEngine::from_forest(kind, precision, &f, None, 6, ShardPolicy::Exact)
            .unwrap()
            .with_topology(arbors::exec::CoreTopology::odroid_xu4());
        // 127 rows: prime, so every lane width leaves a remainder.
        let xe = &x[..d * 127];
        assert_eq!(
            par.predict(xe),
            serial.predict(xe),
            "{} not bit-exact under weighted sharding",
            variant_name(kind, precision)
        );
    }
}
