//! FLInt-carrier exactness contract (ISSUE 8 acceptance): every FLInt
//! engine — flNA, flIE, flQS, flVQS, flRS — must be **bit-identical** to
//! its f32 twin across random forests, batch sizes (including
//! non-multiples of the SIMD lane widths), and 1–8 exec threads (serial
//! `build` + `ParallelEngine` under the default `ShardPolicy::Exact`),
//! with NaN / ±0.0 / denormal / ±inf feature values injected into every
//! batch. Equality is on the raw f32 *bits* (`to_bits`), so a mismatch in
//! any compare decision, mask, leaf pick or accumulation order shows up
//! as a hard failure — the carrier is a virtual precision, not an
//! approximation (DESIGN.md §10).

use arbors::engine::{build, build_parallel, flint_variants, variant_name, Precision};
use arbors::forest::builder::{train_random_forest, RfParams, TreeParams};
use arbors::testing::{bits, Runner, ADVERSARIAL};
use arbors::util::Pcg32;

#[test]
fn flint_engines_bit_identical_to_f32_twins() {
    Runner::new(12).with_seed(0xF117).run(|rng: &mut Pcg32, size| {
        // Random problem shape. Training features include exact zeros so
        // split midpoints can land on the ±0.0 seam the carrier
        // canonicalizes (quant::flint threshold contract).
        let d = rng.range(2, 10);
        let c = rng.range(1, 4).max(1);
        let n_train = 100 + size;
        let mut x = Vec::with_capacity(n_train * d);
        let mut y = Vec::with_capacity(n_train);
        for _ in 0..n_train {
            for _ in 0..d {
                x.push(match rng.below(8) {
                    0 => 0.0,
                    1 => -rng.f32(),
                    _ => rng.f32(),
                });
            }
            y.push(rng.below(c) as u32);
        }
        let f = train_random_forest(
            &x,
            &y,
            d,
            c,
            RfParams {
                n_trees: rng.range(1, 12),
                tree: TreeParams {
                    max_leaves: *rng.choose(&[4usize, 8, 16, 32, 64]),
                    min_samples_leaf: 1,
                    mtry: 0,
                },
                seed: rng.next_u64(),
                ..Default::default()
            },
        );
        // Awkward batch sizes: 1, primes, non-multiples of v=4 (flVQS)
        // and v=16 (flRS).
        let n_eval = *rng.choose(&[1usize, 3, 15, 16, 17, 33, 50 + size % 23]);
        let mut xe: Vec<f32> = (0..n_eval * d)
            .map(|_| if rng.below(4) == 0 { -rng.f32() } else { rng.f32() })
            .collect();
        // Inject adversarial values at random positions (≈1 in 6 entries).
        for v in xe.iter_mut() {
            if rng.below(6) == 0 {
                *v = *rng.choose(&ADVERSARIAL);
            }
        }
        for (kind, precision) in flint_variants() {
            let twin = build(kind, Precision::F32, &f, None).map_err(|e| e.to_string())?;
            let want = twin.predict(&xe);
            let serial = build(kind, precision, &f, None).map_err(|e| e.to_string())?;
            let got = serial.predict(&xe);
            if bits(&got) != bits(&want) {
                let first = got
                    .iter()
                    .zip(&want)
                    .position(|(a, b)| a.to_bits() != b.to_bits())
                    .unwrap_or(0);
                return Err(format!(
                    "{} differs from its f32 twin (n={n_eval}; first mismatch at \
                     flat index {first}: {:?} vs {:?})",
                    variant_name(kind, precision),
                    got[first],
                    want[first],
                ));
            }
            for threads in [2usize, 3, 8] {
                let par = build_parallel(kind, precision, &f, None, threads)
                    .map_err(|e| e.to_string())?;
                if bits(&par.predict(&xe)) != bits(&want) {
                    return Err(format!(
                        "{} × {threads}t differs from the f32 twin at n={n_eval}",
                        variant_name(kind, precision),
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Pure-adversarial batches: every feature is a corner value (NaN, ±0.0,
/// denormals, ±inf). These rows never take the common compare paths, so
/// the NaN-goes-right / -0.0-canonicalization contracts carry the whole
/// result.
#[test]
fn flint_engines_bit_identical_on_pure_corner_batches() {
    Runner::new(8).with_seed(0xF118).run(|rng: &mut Pcg32, size| {
        let d = rng.range(2, 6);
        let c = rng.range(1, 3).max(1);
        let n_train = 80 + size;
        let mut x = Vec::with_capacity(n_train * d);
        let mut y = Vec::with_capacity(n_train);
        for _ in 0..n_train {
            for _ in 0..d {
                x.push(rng.f32() - 0.5);
            }
            y.push(rng.below(c) as u32);
        }
        let f = train_random_forest(
            &x,
            &y,
            d,
            c,
            RfParams {
                n_trees: rng.range(1, 8),
                tree: TreeParams { max_leaves: 16, min_samples_leaf: 1, mtry: 0 },
                seed: rng.next_u64(),
                ..Default::default()
            },
        );
        let n_eval = *rng.choose(&[1usize, 5, 16, 19, 37]);
        let xe: Vec<f32> =
            (0..n_eval * d).map(|_| *rng.choose(&ADVERSARIAL)).collect();
        for (kind, precision) in flint_variants() {
            let want = build(kind, Precision::F32, &f, None)
                .map_err(|e| e.to_string())?
                .predict(&xe);
            for threads in [1usize, 4, 8] {
                let e = build_parallel(kind, precision, &f, None, threads)
                    .map_err(|e| e.to_string())?;
                if bits(&e.predict(&xe)) != bits(&want) {
                    return Err(format!(
                        "{} × {threads}t diverged on a pure corner batch (n={n_eval})",
                        variant_name(kind, precision),
                    ));
                }
            }
        }
        Ok(())
    });
}
