//! Chaos test for the fault-tolerant serving stack (ISSUE 10): the full
//! net → batcher → pool path under injected faults and offered overload.
//!
//! Invariants under test:
//!
//! * **Exactly one reply per accepted request** — every line a
//!   well-behaved client writes gets exactly one response line (scored or
//!   typed error), never zero, never two, even while other clients panic
//!   the engine, send poisoned payloads, blow the line cap, or vanish
//!   mid-request.
//! * **No leaked handler threads** — the registry's live count drains to
//!   zero and [`NetServer::shutdown`] joins every handler within its
//!   deadline, with faulty clients still connected.
//! * **No deadlock** — the whole test is bounded by per-step timeouts; an
//!   injected engine panic or stall must degrade a *batch*, not wedge the
//!   server.
//!
//! Faults are deterministic (`testing::fault` fires on counted calls), so
//! a failure here replays.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use arbors::coordinator::{BatchConfig, NetClient, NetConfig, NetServer, Server};
use arbors::data::DatasetId;
use arbors::engine::{build, Engine, EngineKind, Precision};
use arbors::forest::builder::{train_random_forest, RfParams, TreeParams};
use arbors::testing::fault::{
    disconnect_mid_request, poisoned_rows, PanicEngine, StallEngine, POISONED_LINES,
};
use arbors::util::Json;

fn trained() -> (arbors::forest::Forest, arbors::data::Dataset) {
    let ds = DatasetId::Magic.generate(500, 0xC4A05);
    let f = train_random_forest(
        &ds.x,
        &ds.labels,
        ds.d,
        ds.n_classes,
        RfParams {
            n_trees: 8,
            tree: TreeParams { max_leaves: 16, min_samples_leaf: 2, mtry: 0 },
            ..Default::default()
        },
    );
    (f, ds)
}

/// One raw protocol exchange: write `lines`, read exactly one reply per
/// line, parse each as JSON. Bounded by a socket read timeout so a lost
/// reply fails the test instead of hanging it.
fn exchange(addr: std::net::SocketAddr, lines: &[String]) -> Vec<Json> {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let mut replies = Vec::with_capacity(lines.len());
    for line in lines {
        writer.write_all(line.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        let mut resp = String::new();
        let n = reader.read_line(&mut resp).expect("reply within timeout");
        assert!(n > 0, "server closed connection before replying to {line:?}");
        replies.push(Json::parse(&resp).expect("reply parses"));
    }
    replies
}

fn predict_line(model: &str, x: &[f32], deadline_ms: Option<f64>) -> String {
    let mut req = Json::from_pairs(vec![
        ("model", Json::Str(model.to_string())),
        ("x", Json::array_f32(x)),
    ]);
    if let Some(ms) = deadline_ms {
        req.set("deadline_ms", Json::Num(ms));
    }
    req.dump()
}

/// The chaos scenario: a healthy model, a panic-injected model, and a
/// stall-injected model behind one bounded net front, driven concurrently
/// by well-behaved clients, poisoners, cap-blowers, and vanishing clients
/// at ~4× the pool's comfortable load.
#[test]
fn chaos_faults_never_leak_threads_or_drop_replies() {
    let (f, ds) = trained();
    let server = Arc::new(Server::new());
    server
        .deploy("magic", &f, EngineKind::Vqs, Precision::F32, BatchConfig::default())
        .unwrap();
    // Panics on its 3rd batch, then recovers: one batch's requesters get
    // `internal`, everyone else real scores.
    let panicky: Arc<dyn Engine> = Arc::new(PanicEngine::new(
        Arc::from(build(EngineKind::Rs, Precision::F32, &f, None).unwrap()),
        3,
    ));
    server
        .deploy_engine("flaky", &f, panicky, BatchConfig::default())
        .unwrap();
    // Stalls its first 2 batches 50 ms each: slow, not dead.
    let stalling: Arc<dyn Engine> = Arc::new(StallEngine::new(
        Arc::from(build(EngineKind::Rs, Precision::F32, &f, None).unwrap()),
        Duration::from_millis(50),
        2,
    ));
    server
        .deploy_engine("syrup", &f, stalling, BatchConfig::default())
        .unwrap();

    let net = NetServer::start_with(
        server.clone(),
        "127.0.0.1:0",
        NetConfig {
            max_conns: 128,
            max_line: 16 * 1024,
            join_deadline: Duration::from_secs(10),
        },
    )
    .unwrap();
    let addr = net.addr();

    let mut drivers = Vec::new();
    // 8 well-behaved clients × 25 requests across all three models, some
    // with deadlines: exactly one reply per line, each either scored or a
    // typed error with a known code.
    for t in 0..8usize {
        let ds = ds.clone();
        drivers.push(std::thread::spawn(move || {
            let models = ["magic", "flaky", "syrup"];
            let lines: Vec<String> = (0..25)
                .map(|i| {
                    let deadline = if i % 5 == 4 { Some(200.0) } else { None };
                    predict_line(models[(t + i) % 3], ds.row((t * 25 + i) % ds.n), deadline)
                })
                .collect();
            let replies = exchange(addr, &lines);
            assert_eq!(replies.len(), lines.len());
            for r in &replies {
                let scored = r.get("scores").is_some();
                let code = r
                    .get("error")
                    .and_then(|e| e.get("code"))
                    .and_then(|c| c.as_str())
                    .map(str::to_string);
                assert!(
                    scored
                        || matches!(
                            code.as_deref(),
                            Some("internal") | Some("deadline") | Some("overloaded")
                        ),
                    "unexpected reply: {}",
                    r.dump()
                );
            }
        }));
    }
    // 2 poisoners: malformed wire lines and malformed rows, each line one
    // typed error (or a scored reply for width-correct NaN/∞ rows).
    for _ in 0..2 {
        let ds = ds.clone();
        drivers.push(std::thread::spawn(move || {
            let mut lines: Vec<String> =
                POISONED_LINES.iter().map(|l| l.to_string()).collect();
            for (_, row) in poisoned_rows(ds.d) {
                lines.push(predict_line("magic", &row, None));
            }
            let replies = exchange(addr, &lines);
            assert_eq!(replies.len(), lines.len());
            for r in &replies {
                assert!(
                    r.get("scores").is_some() || r.get("error").is_some(),
                    "reply must be scored or typed error: {}",
                    r.dump()
                );
            }
        }));
    }
    // 2 cap-blowers: a newline-free blob over the line cap gets a typed
    // refusal and a closed connection. Exactly cap+1 bytes: the server
    // consumes all of it before closing, so the close is a clean FIN and
    // the typed reply is reliably readable (an RST from unread bytes
    // could discard it).
    for _ in 0..2 {
        drivers.push(std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
            s.write_all(&vec![b'x'; 16 * 1024 + 1]).unwrap();
            let mut reader = BufReader::new(s);
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let resp = Json::parse(&line).unwrap();
            assert_eq!(
                resp.get("error")
                    .and_then(|e| e.get("code"))
                    .and_then(|c| c.as_str()),
                Some("bad_input")
            );
            line.clear();
            assert_eq!(reader.read_line(&mut line).unwrap(), 0, "must close");
        }));
    }
    // 4 vanishing clients: send a request, drop the socket unread. The
    // handler's reply write fails quietly; nothing leaks.
    for t in 0..4usize {
        let ds = ds.clone();
        drivers.push(std::thread::spawn(move || {
            for i in 0..5 {
                disconnect_mid_request(addr, &predict_line("magic", ds.row(t + i), None))
                    .unwrap();
            }
        }));
    }
    for d in drivers {
        d.join().expect("driver thread must not panic");
    }

    // The panic-injected engine actually fired (its batch produced
    // `internal` errors above or recovered) and the server still answers.
    let mut client = NetClient::connect(addr).unwrap();
    let scores = client.predict("magic", ds.row(0)).unwrap();
    assert_eq!(scores.len(), ds.n_classes);
    // The injected panic fires on one specific batch; if chaos traffic
    // didn't reach it, the first probe here does — either way a healthy
    // reply must arrive within a few attempts.
    let flaky_ok = (0..5).any(|_| client.predict("flaky", ds.row(0)).is_ok());
    assert!(flaky_ok, "flaky model must recover after the injected panic");
    assert!(client.predict("syrup", ds.row(0)).is_ok(), "stalled model must recover");
    drop(client);

    // Teardown: every handler joins, the registry drains to zero.
    let registry = net.handlers_arc();
    assert!(registry.spawned() >= 16, "drivers actually exercised the front");
    let joined = net.shutdown();
    assert!(joined, "handlers not joined within deadline");
    assert_eq!(registry.live(), 0, "leaked handler threads");

    // The server object itself survives for further in-process use.
    assert!(server.predict("magic", ds.row(1).to_vec()).is_ok());
}

/// Deterministic single-model panic scenario: the batch containing the
/// injected panic answers `internal` to every requester exactly once, and
/// the next batch is healthy — counters conserve.
#[test]
fn injected_panic_degrades_one_batch_not_the_server() {
    let (f, ds) = trained();
    let server = Arc::new(Server::new());
    let panicky: Arc<dyn Engine> = Arc::new(PanicEngine::new(
        Arc::from(build(EngineKind::Rs, Precision::F32, &f, None).unwrap()),
        1,
    ));
    server
        .deploy_engine("flaky", &f, panicky, BatchConfig::default())
        .unwrap();
    // First request rides the panicking batch.
    let first = server.predict("flaky", ds.row(0).to_vec());
    assert!(
        matches!(first, Err(arbors::coordinator::ServeError::Internal)),
        "first batch must surface the injected panic, got {first:?}"
    );
    // Later requests are healthy and bit-exact to the serial reference.
    let want = f.predict_batch(ds.row(1));
    let got = server.predict("flaky", ds.row(1).to_vec()).unwrap();
    assert_eq!(got, want);
    let dep = server.model("flaky").unwrap();
    let counters: std::collections::HashMap<&str, u64> =
        dep.batcher.metrics.counters().into_iter().collect();
    assert_eq!(counters["requests"], 2);
    assert_eq!(
        counters["completed"] + counters["failed"],
        2,
        "every request accounted for: {counters:?}"
    );
    assert_eq!(counters["failed"], 1, "exactly the panicked batch failed");
}

/// Stalls long enough to trip request deadlines: requests with tight
/// deadlines shed with the `deadline` code while the stalled batch is in
/// flight, and the connection keeps serving afterwards. Bounded end to
/// end — a wedged server fails the read timeout, not the CI job.
#[test]
fn stall_with_deadlines_sheds_instead_of_wedging() {
    let (f, ds) = trained();
    let server = Arc::new(Server::new());
    let stalling: Arc<dyn Engine> = Arc::new(StallEngine::new(
        Arc::from(build(EngineKind::Rs, Precision::F32, &f, None).unwrap()),
        Duration::from_millis(150),
        1,
    ));
    server
        .deploy_engine("syrup", &f, stalling, BatchConfig::default())
        .unwrap();
    let net = NetServer::start(server, "127.0.0.1:0").unwrap();
    let addr = net.addr();
    let t0 = Instant::now();
    // Request 1 hits the stalled batch (no deadline: it just waits).
    // While it stalls, request 2 on a second connection carries an
    // already-expired deadline (0 ms): admission sheds it with the
    // `deadline` code immediately — the stalled batch must not block the
    // shed path, and the shed must not disturb the stalled batch.
    let row0 = ds.row(0).to_vec();
    let slow = std::thread::spawn(move || {
        exchange(addr, &[predict_line("syrup", &row0, None)])
    });
    std::thread::sleep(Duration::from_millis(20));
    let r = &exchange(addr, &[predict_line("syrup", ds.row(1), Some(0.0))])[0];
    let code = r
        .get("error")
        .and_then(|e| e.get("code"))
        .and_then(|c| c.as_str())
        .map(str::to_string);
    assert_eq!(code.as_deref(), Some("deadline"), "got {}", r.dump());
    let slow_replies = slow.join().unwrap();
    assert!(slow_replies[0].get("scores").is_some(), "stalled request completes");
    assert!(t0.elapsed() < Duration::from_secs(20), "bounded end to end");
    assert!(net.shutdown());
}
