//! Int8-tier exactness contract (the mirror of `parallel_exact.rs` for the
//! new precision tier): every i8 engine — all five families q8NA, q8IE,
//! q8QS, the v=16 q8VQS and q8RS — must be **bit-identical** to the i8
//! naive reference (`QForest::<i8>::predict_batch`, i32 accumulation)
//! across random forests, coarse scales, batch sizes (including
//! non-multiples of the 16-lane width), 1–8 exec threads, both
//! accumulation modes, and both scaling modes (global and per-tree leaf
//! scales). Equality is `==` on the f32 bits: both sides descale the same
//! i32 sums, so any accumulator wrap, lane-masking or shift-rounding bug
//! shows up as a hard mismatch.

use std::sync::Arc;

use arbors::engine::{build, build_parallel, i8_variants, variant_name, Engine};
use arbors::exec::ParallelEngine;
use arbors::forest::builder::{train_random_forest, RfParams, TreeParams};
use arbors::quant::{
    choose_scale_i8, choose_scale_i8_per_tree, max_safe_scale_with, AccumMode, QForest,
    QuantConfig,
};
use arbors::testing::Runner;
use arbors::util::Pcg32;

#[test]
fn i8_engines_bit_identical_to_i8_reference() {
    Runner::new(12).with_seed(0x18E).run(|rng: &mut Pcg32, size| {
        // Random problem shape.
        let d = rng.range(2, 10);
        let c = rng.range(1, 4).max(1);
        let n_train = 100 + size;
        let mut x = Vec::with_capacity(n_train * d);
        let mut y = Vec::with_capacity(n_train);
        for _ in 0..n_train {
            for _ in 0..d {
                x.push(rng.f32());
            }
            y.push(rng.below(c) as u32);
        }
        let f = train_random_forest(
            &x,
            &y,
            d,
            c,
            RfParams {
                n_trees: rng.range(1, 12),
                tree: TreeParams {
                    max_leaves: *rng.choose(&[4usize, 8, 16, 32, 64]),
                    min_samples_leaf: 1,
                    mtry: 0,
                },
                seed: rng.next_u64(),
                ..Default::default()
            },
        );
        // Random (coarse!) scales exercise real threshold collisions; the
        // cap keeps thresholds/leaves inside i8 storage and the widened
        // i16 accumulator wrap-free, so the i32-accumulating reference
        // cannot diverge. The tier's own chosen scale is always included.
        let cap = max_safe_scale_with(&f, 1.0, i8::MAX as f32, i16::MAX as f32);
        let coarse =
            QuantConfig::<i8>::new(rng.choose(&[4.0f32, 16.0, 64.0, 127.0]).min(cap).max(1.0));
        for cfg in [coarse, choose_scale_i8(&f, 1.0)] {
            let qf = QForest::<i8>::from_forest(&f, cfg);
            // Awkward batch sizes: 1, primes, non-multiples of v = 16.
            let n_eval = *rng.choose(&[1usize, 3, 15, 16, 17, 33, 50 + size % 23]);
            let xe: Vec<f32> = (0..n_eval * d).map(|_| rng.f32()).collect();
            let want = qf.predict_batch(&xe);
            // The engine::build path carries the scale in an i16-typed
            // config and re-materializes it at i8.
            let carrier: QuantConfig = QuantConfig::new(cfg.scale);
            for (kind, precision) in i8_variants() {
                let serial =
                    build(kind, precision, &f, Some(carrier)).map_err(|e| e.to_string())?;
                let got = serial.predict(&xe);
                if got != want {
                    let first =
                        got.iter().zip(&want).position(|(a, b)| a != b).unwrap_or(0);
                    return Err(format!(
                        "{} differs from the i8 reference (scale {}, n={n_eval}; \
                         first mismatch at flat index {first}: {} vs {})",
                        variant_name(kind, precision),
                        cfg.scale,
                        got[first],
                        want[first],
                    ));
                }
                for threads in [2usize, 3, 8] {
                    let par = build_parallel(kind, precision, &f, Some(carrier), threads)
                        .map_err(|e| e.to_string())?;
                    if par.predict(&xe) != want {
                        return Err(format!(
                            "{} × {threads}t differs from serial at n={n_eval} \
                             (scale {})",
                            variant_name(kind, precision),
                            cfg.scale,
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Per-tree leaf scales (InTreeger-style scale/shift): every i8 engine,
/// built directly from a per-tree-quantized forest, is bit-identical to
/// the shifted i32 reference across random forests, batch sizes and 1–8
/// threads.
#[test]
fn i8_engines_bit_identical_under_per_tree_scales() {
    Runner::new(10).with_seed(0x9E7).run(|rng: &mut Pcg32, size| {
        let d = rng.range(2, 9);
        let c = rng.range(1, 4).max(1);
        let n_train = 100 + size;
        let mut x = Vec::with_capacity(n_train * d);
        let mut y = Vec::with_capacity(n_train);
        for _ in 0..n_train {
            for _ in 0..d {
                x.push(rng.f32());
            }
            y.push(rng.below(c) as u32);
        }
        let f = train_random_forest(
            &x,
            &y,
            d,
            c,
            RfParams {
                n_trees: rng.range(2, 16),
                tree: TreeParams {
                    max_leaves: *rng.choose(&[8usize, 16, 32, 64]),
                    min_samples_leaf: 1,
                    mtry: 0,
                },
                seed: rng.next_u64(),
                ..Default::default()
            },
        );
        let cfg = choose_scale_i8_per_tree(&f, 1.0);
        let qf = QForest::<i8>::from_forest_per_tree(&f, cfg);
        let n_eval = *rng.choose(&[1usize, 7, 16, 17, 33, 40 + size % 19]);
        let xe: Vec<f32> = (0..n_eval * d).map(|_| rng.f32()).collect();
        let want = qf.predict_batch(&xe);
        // Per-tree QForests are built explicitly (the `build` API upgrades
        // to per-tree only when global scaling widens), so construct each
        // engine from the same quantized forest.
        let engines: Vec<(&str, Arc<dyn Engine>)> = vec![
            ("q8NA", Arc::new(arbors::engine::naive::QNaiveEngine::new(&qf))),
            ("q8IE", Arc::new(arbors::engine::ifelse::QIfElseEngine::new(&qf))),
            ("q8QS", Arc::new(arbors::engine::quickscorer::QQsEngine::new(&qf))),
            ("q8VQS", Arc::new(arbors::engine::vqs::QVqs8Engine::new(&qf))),
            ("q8RS", Arc::new(arbors::engine::rapidscorer::QRs8Engine::new(&qf))),
        ];
        for (name, e) in engines {
            if e.predict(&xe) != want {
                return Err(format!(
                    "{name} differs from the per-tree i8 reference \
                     (scale {}, n={n_eval})",
                    cfg.scale
                ));
            }
            for threads in [2usize, 8] {
                let par = ParallelEngine::wrap(e.clone(), threads);
                if par.predict(&xe) != want {
                    return Err(format!(
                        "{name} × {threads}t differs under per-tree scales at n={n_eval}"
                    ));
                }
            }
        }
        Ok(())
    });
}

/// The acceptance property of per-tree scaling, end to end through
/// `engine::build`: on a forest where the global analysis required
/// `Widened`, per-tree scaling flips `accum_mode` to `Native`, `build`
/// adopts it, and every engine family (serial and threaded) matches the
/// per-tree reference.
#[test]
fn per_tree_scaling_flips_accum_mode_and_build_adopts_it() {
    use arbors::forest::{Forest, Task, Tree};
    // 60 constant trees with |leaf| ≤ 1/30 (RF-style 1/M leaves): the
    // global leaf floor M = 60 exceeds the native budget, forcing Widened;
    // per-tree scales restore Native.
    let mut f = Forest::new(3, 1, Task::Ranking);
    for i in 0..60 {
        f.trees.push(Tree::leaf(vec![(1.0 + (i % 4) as f32) / 120.0]));
    }
    let qf_global = QForest::<i8>::from_forest(&f, choose_scale_i8(&f, 1.0));
    assert_eq!(qf_global.accum_mode(), AccumMode::Widened, "premise: global widens");
    let qf_pt = QForest::<i8>::from_forest_per_tree(&f, choose_scale_i8_per_tree(&f, 1.0));
    assert_eq!(qf_pt.accum_mode(), AccumMode::Native, "per-tree must flip to Native");
    assert!(qf_pt.has_per_tree_scales());

    let mut rng = Pcg32::seeded(0x9E8);
    let xe: Vec<f32> = (0..33 * 3).map(|_| rng.f32()).collect();
    let want = qf_pt.predict_batch(&xe);
    for (kind, precision) in i8_variants() {
        let e = build(kind, precision, &f, None).unwrap();
        assert_eq!(
            e.predict(&xe),
            want,
            "{} did not adopt per-tree scaling",
            variant_name(kind, precision)
        );
        for threads in [2usize, 5] {
            let par = build_parallel(kind, precision, &f, None, threads).unwrap();
            assert_eq!(
                par.predict(&xe),
                want,
                "{} × {threads}t diverges under per-tree scaling",
                variant_name(kind, precision)
            );
        }
    }
}

/// The widened accumulation path (worst-case sum cannot fit i8) stays
/// bit-exact too — all five engines against the reference on a forest
/// whose leaf magnitudes force `AccumMode::Widened`.
#[test]
fn i8_engines_exact_in_widened_mode() {
    let mut rng = Pcg32::seeded(0x1DE);
    let d = 8;
    let n = 400;
    let x: Vec<f32> = (0..n * d).map(|_| rng.f32()).collect();
    let y: Vec<u32> = (0..n).map(|_| rng.below(3) as u32).collect();
    let mut f = train_random_forest(
        &x,
        &y,
        d,
        3,
        RfParams {
            n_trees: 14,
            tree: TreeParams { max_leaves: 32, min_samples_leaf: 2, mtry: 0 },
            ..Default::default()
        },
    );
    for t in &mut f.trees {
        for v in &mut t.leaf_values {
            *v *= 30.0;
        }
    }
    let cfg = choose_scale_i8(&f, 1.0);
    let qf = QForest::<i8>::from_forest(&f, cfg);
    assert_eq!(qf.accum_mode(), AccumMode::Widened);
    // 127 rows: prime, so the 16-lane blocking leaves a remainder.
    let xe = &x[..d * 127];
    let want = qf.predict_batch(xe);
    let carrier: QuantConfig = QuantConfig::new(cfg.scale);
    for (kind, precision) in i8_variants() {
        let e = build(kind, precision, &f, Some(carrier)).unwrap();
        assert_eq!(
            e.predict(xe),
            want,
            "{} not bit-exact in widened mode",
            variant_name(kind, precision)
        );
    }
}
