//! Early-exit exactness contract (ISSUE 9 acceptance): exact-mode staged
//! scoring must produce the **identical argmax** to scoring every stage
//! (mode `Off` — same confidence order, same staging, no exits) for every
//! engine family × precision tier × batch size × 1–8 exec threads,
//! including forests engineered so two classes sit within one leaf weight
//! of each other and batches seeded with NaN / ±0.0 / denormal / ±inf
//! features (the shared `testing::inject` adversary). Score equality is
//! *not* required — skipping stages changes the f32 sums — decision
//! equality is: early exit changes what "correct" means (DESIGN.md §11).
//! Threaded exact-mode scores additionally stay bit-identical to serial
//! exact-mode scores (row sharding never splits a row, so per-row exit
//! decisions are scheduler-independent).

use std::sync::Arc;

use arbors::engine::{
    all_variants_with_i8, build_early_exit, variant_name, EarlyExitMode, Engine,
};
use arbors::exec::ParallelEngine;
use arbors::forest::builder::{train_random_forest, RfParams, TreeParams};
use arbors::forest::{Child, Forest, Node, Task, Tree};
use arbors::testing::{bits, Runner, ADVERSARIAL};
use arbors::util::Pcg32;

/// A depth-1 stump `x[feature] <= threshold ? left : right` — the smallest
/// tree every engine family traverses (leaf-only trees skip the compare
/// paths this suite needs to stress).
fn stump(feature: u32, threshold: f32, left: Vec<f32>, right: Vec<f32>) -> Tree {
    let n_classes = left.len();
    let mut leaf_values = left;
    leaf_values.extend(right);
    Tree {
        nodes: vec![Node {
            feature,
            threshold,
            left: Child::Leaf(0),
            right: Child::Leaf(1),
        }],
        leaf_values,
        n_leaves: 2,
        n_classes,
    }
}

/// Exact-mode argmax equals off-mode (full staged scoring) argmax for every
/// registered variant, serially and across thread counts, on `xe`.
fn check_all_variants(f: &Forest, cal: &[f32], xe: &[f32]) -> Result<(), String> {
    let c = f.n_classes;
    for (kind, precision) in all_variants_with_i8() {
        // >64-leaf forests drop the QS family — same skip as the registry.
        let Ok(off) = build_early_exit(kind, precision, f, cal, EarlyExitMode::Off) else {
            continue;
        };
        let exact = build_early_exit(kind, precision, f, cal, EarlyExitMode::Exact)
            .map_err(|e| e.to_string())?;
        let want = Forest::argmax(&off.predict(xe), c);
        let serial_scores = exact.predict(xe);
        if Forest::argmax(&serial_scores, c) != want {
            let got = Forest::argmax(&serial_scores, c);
            let first = got.iter().zip(&want).position(|(a, b)| a != b).unwrap_or(0);
            return Err(format!(
                "{}: exact early exit changed the argmax (row {first}: {} vs {})",
                variant_name(kind, precision),
                got[first],
                want[first],
            ));
        }
        let shared: Arc<dyn Engine> = Arc::new(exact);
        for threads in [2usize, 3, 8] {
            let par = ParallelEngine::wrap(shared.clone(), threads);
            let got = par.predict(xe);
            // Row sharding must not perturb per-row exit decisions: the
            // threaded scores are bit-identical to the serial wrapper's.
            if bits(&got) != bits(&serial_scores) {
                return Err(format!(
                    "{} × {threads}t: threaded exact scores diverged from serial",
                    variant_name(kind, precision),
                ));
            }
            if Forest::argmax(&got, c) != want {
                return Err(format!(
                    "{} × {threads}t: exact early exit changed the argmax",
                    variant_name(kind, precision),
                ));
            }
        }
    }
    Ok(())
}

/// Trained random forests × every variant × awkward batch sizes × threads,
/// with adversarial corner values injected into every evaluation batch.
#[test]
fn exact_argmax_identical_on_trained_forests() {
    Runner::new(6).with_seed(0xEE01).run(|rng: &mut Pcg32, size| {
        let d = rng.range(2, 8);
        let c = rng.range(2, 5);
        let n_train = 120 + size;
        let mut x = Vec::with_capacity(n_train * d);
        let mut y = Vec::with_capacity(n_train);
        for _ in 0..n_train {
            for _ in 0..d {
                x.push(match rng.below(8) {
                    0 => 0.0,
                    1 => -rng.f32(),
                    _ => rng.f32(),
                });
            }
            y.push(rng.below(c) as u32);
        }
        let f = train_random_forest(
            &x,
            &y,
            d,
            c,
            RfParams {
                n_trees: rng.range(4, 16),
                tree: TreeParams {
                    max_leaves: *rng.choose(&[4usize, 8, 16, 32, 64]),
                    min_samples_leaf: 1,
                    mtry: 0,
                },
                seed: rng.next_u64(),
                ..Default::default()
            },
        );
        // Calibration from the training distribution (any calibration is
        // sound for exact mode — it only permutes the tree order).
        let cal = &x[..d * (n_train.min(64))];
        // Awkward batch sizes: 1, primes, non-multiples of v=4 and v=16.
        let n_eval = *rng.choose(&[1usize, 3, 15, 16, 17, 33, 50 + size % 23]);
        let mut xe: Vec<f32> = (0..n_eval * d)
            .map(|_| if rng.below(4) == 0 { -rng.f32() } else { rng.f32() })
            .collect();
        // Inject adversarial values at random positions (≈1 in 6 entries):
        // NaN margins must fail safe into full scoring, never a wrong exit.
        for v in xe.iter_mut() {
            if rng.below(6) == 0 {
                *v = *rng.choose(&ADVERSARIAL);
            }
        }
        check_all_variants(&f, cal, &xe)
    });
}

/// Adversarial tie-margin forests: stumps whose two classes stay within one
/// leaf weight of each other — exact ties (margin 0) and sub-leaf-weight
/// near-ties the suffix bound must never resolve early, with routing (and
/// thus the winner) controlled by corner-value features crossing the ±0.0
/// threshold seam.
#[test]
fn exact_argmax_identical_on_tie_margin_forests() {
    Runner::new(8).with_seed(0xEE02).run(|rng: &mut Pcg32, size| {
        let d = 3usize;
        let c = 2usize;
        let w = 0.5f32; // the leaf weight all margins stay under
        let n_trees = rng.range(3, 9).max(3);
        let mut f = Forest::new(d, c, Task::Classification);
        for t in 0..n_trees {
            // Per-tree class imbalance strictly below one leaf weight —
            // 0.0 makes the tree a pure tie contributor.
            let delta = *rng.choose(&[0.0f32, 1e-7, 1e-3, 0.25 * w]);
            // Threshold 0.0 puts the split on the ±0.0 seam; NaN features
            // compare false and route right.
            let threshold = *rng.choose(&[0.0f32, 0.5]);
            f.trees.push(stump(
                (t % d) as u32,
                threshold,
                vec![w, w - delta],
                vec![w - delta, w],
            ));
        }
        let cal: Vec<f32> = (0..d * 16).map(|_| rng.f32() - 0.5).collect();
        let n_eval = *rng.choose(&[1usize, 7, 16, 33]);
        let xe: Vec<f32> = (0..n_eval * d)
            .map(|_| match rng.below(3) {
                // Pure corner rows: every feature is an adversary value.
                0 => *rng.choose(&ADVERSARIAL),
                1 => rng.f32() - 0.5,
                _ => rng.f32(),
            })
            .collect();
        let _ = size;
        check_all_variants(&f, &cal, &xe)
    });
}

/// The tie-break direction itself: a forest summing to an exact tie must
/// pick class 0 (first-index strict-`>` argmax) through every variant and
/// mode — a single flipped comparison in the exit test would surface here.
#[test]
fn exact_ties_resolve_by_index_everywhere() {
    let d = 2usize;
    let mut f = Forest::new(d, 2, Task::Classification);
    for t in 0..5 {
        // Symmetric stumps: both branches contribute [0.4, 0.4].
        f.trees.push(stump((t % d) as u32, 0.25, vec![0.4, 0.4], vec![0.4, 0.4]));
    }
    let xe: Vec<f32> = vec![0.0, 1.0, -0.0, 0.25, f32::NAN, 0.5, 1.0, -1.0];
    for (kind, precision) in all_variants_with_i8() {
        let exact = build_early_exit(kind, precision, &f, &[], EarlyExitMode::Exact).unwrap();
        let preds = Forest::argmax(&exact.predict(&xe), 2);
        assert_eq!(
            preds,
            vec![0u32; 4],
            "{}: exact tie must resolve to class 0",
            variant_name(kind, precision)
        );
    }
}
