//! Property tests for the fused serving scheduler (ISSUE 3): one
//! server-shared work-stealing pool from request to SIMD lane.
//!
//! Invariants under test:
//!
//! * **Bit-exactness**: fused shared-pool execution equals serial
//!   `Engine::predict_batch` bit-for-bit under `ShardPolicy::Exact` row
//!   plans, for every engine tier (f32 / i16 / i8), every pool size 1–8,
//!   every per-deployment budget, and with ≥ 2 deployments running
//!   concurrently.
//! * **Pairing**: every reply carries exactly the scores of the row its
//!   requester submitted, across concurrent clients, deployments and batch
//!   sizes — including under backpressure (`Overloaded`).
//! * **One pool**: a `Server` with two deployments spawns exactly one
//!   worker pool; deploy/redeploy/undeploy never add exec threads.
//!
//! Tests serialize on a file-local mutex: the spawned-worker-thread counter
//! is process-wide, and unserialized pool spawns would make its deltas
//! meaningless.

use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use arbors::coordinator::{BatchConfig, ServeError, Server};
use arbors::data::DatasetId;
use arbors::engine::{build, EngineKind, Precision};
use arbors::forest::builder::{train_random_forest, RfParams, TreeParams};
use arbors::forest::Forest;

static SERIAL: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    // A panicking test must not wedge the rest of the file.
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn forest(trees: usize) -> (Forest, arbors::data::Dataset) {
    let ds = DatasetId::Magic.generate(700, 0xF5);
    let f = train_random_forest(
        &ds.x,
        &ds.labels,
        ds.d,
        ds.n_classes,
        RfParams {
            n_trees: trees,
            tree: TreeParams { max_leaves: 16, min_samples_leaf: 2, mtry: 0 },
            ..Default::default()
        },
    );
    (f, ds)
}

/// Exactly one pool for any number of deployments, across redeploys.
#[test]
fn one_pool_for_all_deployments() {
    let _g = lock();
    let (f, ds) = forest(10);
    let before = arbors::exec::worker_threads_spawned();
    let server = Server::with_pool_size(3);
    server
        .deploy(
            "a",
            &f,
            EngineKind::Rs,
            Precision::F32,
            BatchConfig { exec_threads: 2, ..BatchConfig::default() },
        )
        .unwrap();
    server
        .deploy(
            "b",
            &f,
            EngineKind::Vqs,
            Precision::I16,
            BatchConfig { exec_threads: 2, ..BatchConfig::default() },
        )
        .unwrap();
    assert_eq!(server.pool_threads(), 3);
    assert_eq!(server.pool_deployments(), 2);
    // Both deployments actually serve through that pool.
    assert_eq!(server.predict("a", ds.row(0).to_vec()).unwrap().len(), f.n_classes);
    assert_eq!(server.predict("b", ds.row(1).to_vec()).unwrap().len(), f.n_classes);
    // The server spawned its 3 pool workers and nothing else — deployments
    // (and their flushes) added zero exec threads.
    assert_eq!(
        arbors::exec::worker_threads_spawned() - before,
        3,
        "deployments must not spawn their own pools"
    );
    // Redeploy tears the old registration down and adds a fresh one; still
    // the same single pool.
    server
        .deploy("a", &f, EngineKind::Qs, Precision::F32, BatchConfig::default())
        .unwrap();
    assert_eq!(arbors::exec::worker_threads_spawned() - before, 3);
    assert_eq!(server.predict("a", ds.row(2).to_vec()).unwrap().len(), f.n_classes);
    // Undeploy unregisters (allow the drained client's drop to land).
    assert!(server.undeploy("b"));
    for _ in 0..500 {
        if server.pool_deployments() == 1 {
            break;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    assert_eq!(server.pool_deployments(), 1);
    assert_eq!(server.pool_threads(), 3);
}

/// The acceptance property: fused execution is bit-exact with the serial
/// engine and replies stay paired, for every tier × pool size 1–8, with
/// three concurrent deployments and three concurrent clients each.
#[test]
fn fused_bit_exact_and_paired_across_tiers_pools_deployments() {
    let _g = lock();
    let (f, ds) = forest(12);
    let tiers: [(EngineKind, Precision); 3] = [
        (EngineKind::Rs, Precision::F32),
        (EngineKind::Rs, Precision::I16),
        (EngineKind::Vqs, Precision::I8),
    ];
    for pool_size in [1usize, 2, 5, 8] {
        let server = Arc::new(Server::with_pool_size(pool_size));
        let mut refs: Vec<Arc<Vec<f32>>> = Vec::new();
        for (mi, &(kind, precision)) in tiers.iter().enumerate() {
            let config = BatchConfig {
                // Different batch shapes per deployment.
                max_batch: 16 << mi,
                max_delay: Duration::from_micros(200),
                queue_cap: 10_000,
                workers: 1,
                // Budgets both below and above the pool size.
                exec_threads: 1 + (pool_size + mi) % 4,
                drain_timeout: None,
                adaptive: true,
            };
            server.deploy(&format!("m{mi}"), &f, kind, precision, config).unwrap();
            // The serial reference builds the same engine the deployment
            // built (same auto-chosen quant scale), so equality is bitwise.
            let serial = build(kind, precision, &f, None).unwrap();
            refs.push(Arc::new(serial.predict(&ds.x)));
        }
        assert_eq!(server.pool_deployments(), 3);
        assert_eq!(server.pool_threads(), pool_size);
        let mut handles = Vec::new();
        for mi in 0..tiers.len() {
            for t in 0..3usize {
                let server = server.clone();
                let ds = ds.clone();
                let want = refs[mi].clone();
                handles.push(std::thread::spawn(move || {
                    let dep = server.model(&format!("m{mi}")).unwrap();
                    for r in 0..60usize {
                        let i = (t * 61 + r * 7 + mi * 13) % ds.n;
                        let got = dep.batcher.predict(ds.row(i).to_vec()).unwrap();
                        assert_eq!(
                            &got[..],
                            &want[i * ds.n_classes..(i + 1) * ds.n_classes],
                            "pool={pool_size} model=m{mi} client={t} row={i}: \
                             reply not bit-exact / mispaired"
                        );
                    }
                }));
            }
        }
        for h in handles {
            h.join().unwrap();
        }
        // Nothing was lost: 3 deployments × 3 clients × 60 requests.
        let total: u64 = (0..tiers.len())
            .map(|mi| {
                server
                    .model(&format!("m{mi}"))
                    .unwrap()
                    .batcher
                    .metrics
                    .completed
                    .load(std::sync::atomic::Ordering::Relaxed)
            })
            .sum();
        assert_eq!(total, 3 * 3 * 60);
    }
}

/// Backpressure: `Overloaded` rejections are clean — every accepted request
/// still gets a correctly-paired, bit-exact reply, and the accounting adds
/// up.
#[test]
fn backpressure_keeps_replies_paired() {
    let _g = lock();
    let (f, ds) = forest(8);
    let server = Server::with_pool_size(2);
    server
        .deploy(
            "m",
            &f,
            EngineKind::Vqs,
            Precision::F32,
            BatchConfig {
                max_batch: 1024,
                max_delay: Duration::from_millis(200),
                queue_cap: 4,
                workers: 1,
                exec_threads: 2,
                drain_timeout: None,
                adaptive: true,
            },
        )
        .unwrap();
    let serial = build(EngineKind::Vqs, Precision::F32, &f, None).unwrap();
    let want = serial.predict(&ds.x);
    let dep = server.model("m").unwrap();
    let mut accepted = Vec::new();
    let mut rejected = 0usize;
    for i in 0..256 {
        match dep.batcher.submit(ds.row(i % ds.n).to_vec()) {
            Ok(rx) => accepted.push((i % ds.n, rx)),
            Err(ServeError::Overloaded) => rejected += 1,
            Err(e) => panic!("unexpected error: {e}"),
        }
    }
    assert!(rejected > 0, "queue_cap=4 must reject under a 256-request burst");
    for (i, rx) in accepted.iter_mut() {
        let got = rx.recv().unwrap().unwrap();
        assert_eq!(
            &got[..],
            &want[*i * ds.n_classes..(*i + 1) * ds.n_classes],
            "row {i} mispaired under backpressure"
        );
    }
    let m = &dep.batcher.metrics;
    use std::sync::atomic::Ordering;
    assert_eq!(m.rejected.load(Ordering::Relaxed) as usize, rejected);
    assert_eq!(m.completed.load(Ordering::Relaxed) as usize, accepted.len());
    assert_eq!(
        m.requests.load(Ordering::Relaxed) as usize,
        accepted.len() + rejected,
        "accepted + rejected must cover every submission"
    );
}

/// Shutdown drain end-to-end through the server: undeploying while requests
/// are queued replies `Shutdown` (never hangs, never drops a reply channel
/// without an answer).
#[test]
fn undeploy_sheds_queued_requests() {
    let _g = lock();
    let (f, ds) = forest(6);
    let server = Server::with_pool_size(2);
    server
        .deploy(
            "m",
            &f,
            EngineKind::Naive,
            Precision::F32,
            BatchConfig {
                max_batch: 1024,
                max_delay: Duration::from_secs(30),
                queue_cap: 1024,
                workers: 1,
                exec_threads: 2,
                drain_timeout: None,
                adaptive: true,
            },
        )
        .unwrap();
    let dep = server.model("m").unwrap();
    let replies: Vec<_> =
        (0..12).map(|i| dep.batcher.submit(ds.row(i).to_vec()).unwrap()).collect();
    std::thread::sleep(Duration::from_millis(20));
    assert!(server.undeploy("m"));
    drop(dep); // the last Deployment handle: batcher drop runs its drain
    for r in replies {
        assert_eq!(r.recv().unwrap(), Err(ServeError::Shutdown));
    }
}
