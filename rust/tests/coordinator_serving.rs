//! Integration tests over the serving stack: batcher invariants under load,
//! backpressure behaviour, selector × server composition, and the tensor
//! engine behind the batcher (when artifacts exist).

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use arbors::coordinator::{BatchConfig, Server};
use arbors::data::DatasetId;
use arbors::engine::{EngineKind, Precision};
use arbors::forest::builder::{train_random_forest, RfParams, TreeParams};
use arbors::forest::Forest;

fn forest(trees: usize) -> (Forest, arbors::data::Dataset) {
    let ds = DatasetId::Adult.generate(800, 0x5E);
    let f = train_random_forest(
        &ds.x,
        &ds.labels,
        ds.d,
        ds.n_classes,
        RfParams {
            n_trees: trees,
            tree: TreeParams { max_leaves: 32, min_samples_leaf: 2, mtry: 0 },
            ..Default::default()
        },
    );
    (f, ds)
}

/// No request is lost or reordered across many concurrent clients — every
/// reply matches the reference scores for the submitted row.
#[test]
fn no_request_lost_or_cross_wired() {
    let (f, ds) = forest(8);
    let server = Arc::new(Server::new());
    server
        .deploy(
            "m",
            &f,
            EngineKind::Vqs,
            Precision::F32,
            BatchConfig {
                max_batch: 32,
                max_delay: Duration::from_micros(100),
                queue_cap: 10_000,
                workers: 3,
                exec_threads: 1,
                drain_timeout: None,
                adaptive: true,
            },
        )
        .unwrap();
    let want = f.predict_batch(&ds.x);
    let n_clients = 8;
    let per_client = 200;
    let mut handles = Vec::new();
    for t in 0..n_clients {
        let server = server.clone();
        let ds = ds.clone();
        let want = want.clone();
        handles.push(std::thread::spawn(move || {
            let dep = server.model("m").unwrap();
            for r in 0..per_client {
                let i = (t * per_client + r) % ds.n;
                let scores = dep.batcher.predict(ds.row(i).to_vec()).unwrap();
                let expect = &want[i * ds.n_classes..(i + 1) * ds.n_classes];
                assert_eq!(&scores[..], expect, "client {t} row {i}");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let dep = server.model("m").unwrap();
    assert_eq!(
        dep.batcher.metrics.completed.load(Ordering::Relaxed),
        (n_clients * per_client) as u64
    );
}

/// The batcher actually batches: under a burst, mean batch size must exceed
/// one (SIMD lanes get filled).
#[test]
fn batches_form_under_burst() {
    let (f, ds) = forest(16);
    let server = Server::new();
    server
        .deploy(
            "m",
            &f,
            EngineKind::Rs,
            Precision::F32,
            BatchConfig {
                max_batch: 64,
                max_delay: Duration::from_millis(2),
                queue_cap: 10_000,
                workers: 1,
                exec_threads: 1,
                drain_timeout: None,
                adaptive: true,
            },
        )
        .unwrap();
    let dep = server.model("m").unwrap();
    let replies: Vec<_> =
        (0..512).map(|i| dep.batcher.submit(ds.row(i % ds.n).to_vec()).unwrap()).collect();
    for r in replies {
        r.recv().unwrap().unwrap();
    }
    let mean = dep.batcher.metrics.mean_batch_size();
    assert!(mean > 2.0, "mean batch size {mean} — batching not effective");
}

/// Deploy → undeploy → redeploy cycles are clean (no thread leaks panics).
#[test]
fn redeploy_cycles() {
    let (f, ds) = forest(4);
    let server = Server::new();
    for _ in 0..3 {
        server
            .deploy("m", &f, EngineKind::Qs, Precision::F32, BatchConfig::default())
            .unwrap();
        let s = server.predict("m", ds.row(0).to_vec()).unwrap();
        assert_eq!(s.len(), f.n_classes);
        assert!(server.undeploy("m"));
    }
}

/// Auto-deployment picks a sane engine and serves correctly.
#[test]
fn auto_deploy_serves_correct_scores() {
    let (f, ds) = forest(12);
    let server = Server::new();
    let sel = server
        .deploy_auto("auto", &f, &ds.x[..ds.d * 64], BatchConfig::default())
        .unwrap();
    assert!(!sel.candidates.is_empty());
    // Auto may choose any quantized tier (i16 or i8, timing-dependent):
    // scores must still rank near-identically to the float reference.
    let mut agree = 0usize;
    for i in 0..32 {
        let want = f.predict_batch(ds.row(i));
        let got = server.predict("auto", ds.row(i).to_vec()).unwrap();
        assert_eq!(got.len(), f.n_classes);
        if Forest::argmax(&want, f.n_classes) == Forest::argmax(&got, f.n_classes) {
            agree += 1;
        }
    }
    assert!(agree >= 24, "only {agree}/32 argmax agreements with float");
}

/// A deployment with an exec-thread budget serves bit-identical scores to
/// the serial engine (the ParallelEngine Exact contract, end to end through
/// the batcher), and its engine name advertises the budget.
#[test]
fn threaded_deployment_bit_exact() {
    let (f, ds) = forest(12);
    let server = Server::new();
    server
        .deploy(
            "m",
            &f,
            EngineKind::Rs,
            Precision::F32,
            BatchConfig { exec_threads: 4, ..BatchConfig::default() },
        )
        .unwrap();
    let dep = server.model("m").unwrap();
    assert_eq!(dep.engine_name, "RS×4t");
    let serial = arbors::engine::build(EngineKind::Rs, Precision::F32, &f, None).unwrap();
    let want = serial.predict(&ds.x[..ds.d * 64]);
    for i in 0..64 {
        let got = server.predict("m", ds.row(i).to_vec()).unwrap();
        assert_eq!(&got[..], &want[i * ds.n_classes..(i + 1) * ds.n_classes], "row {i}");
    }
}

/// Auto-deploy with a thread budget enumerates threaded candidates next to
/// the serial ten and deploys something that serves correctly.
#[test]
fn auto_deploy_with_thread_budget() {
    let (f, ds) = forest(12);
    let server = Server::new();
    let sel = server
        .deploy_auto(
            "auto",
            &f,
            &ds.x[..ds.d * 64],
            BatchConfig { exec_threads: 2, ..BatchConfig::default() },
        )
        .unwrap();
    // Every registered variant (plus the i16 per-tree candidate) × thread
    // budgets {1, 2}; derived from the engine registry (the literal here
    // went stale as tiers grew).
    assert_eq!(
        sel.candidates.len(),
        2 * (arbors::engine::all_variants_with_i8().len() + 1)
    );
    assert!(sel.candidates.iter().any(|c| c.threads == 2));
    let got = server.predict("auto", ds.row(3).to_vec()).unwrap();
    assert_eq!(got.len(), f.n_classes);
}

/// Tensor engine behind the batcher (requires artifacts).
#[test]
fn tensor_engine_served() {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let metas = arbors::runtime::load_manifest(&dir).unwrap();
    let meta = metas.iter().find(|m| m.name == "rf_f32_b64").unwrap();
    let forest = arbors::forest::io::load(&dir.join(&meta.forest)).unwrap();
    let engine =
        arbors::engine::tensor::TensorEngine::from_artifact(&dir, "rf_f32_b64", &forest)
            .unwrap();
    let server = Server::new();
    server
        .deploy_engine("xla", &forest, Arc::new(engine), BatchConfig::default())
        .unwrap();
    let mut rng = arbors::util::Pcg32::seeded(0x7E);
    let row: Vec<f32> = (0..forest.n_features).map(|_| rng.f32()).collect();
    let want = forest.predict_batch(&row);
    let got = server.predict("xla", row).unwrap();
    arbors::testing::assert_close(&got, &want, 1e-4, 1e-4).unwrap();
}
