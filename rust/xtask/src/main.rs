//! `cargo run -p xtask -- audit` — the repo's correctness audit.
//!
//! Walks the Rust sources (`rust/src`, `rust/tests`, `rust/benches`,
//! `examples`) and applies the lint catalogue in [`lints`] (documented in
//! DESIGN.md §9). Emits `file:line: [lint-id] message` findings, lists
//! inline waivers, and exits nonzero when any finding survives. `rust/vendor`
//! (third-party stand-ins) and `rust/xtask` itself (its sources and fixtures
//! quote lint patterns) are out of scope.

use std::path::{Path, PathBuf};

mod lints;
mod scan;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cmd: Option<&str> = None;
    let mut root: Option<PathBuf> = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--root" => {
                i += 1;
                match args.get(i) {
                    Some(p) => root = Some(PathBuf::from(p)),
                    None => usage("--root needs a directory"),
                }
            }
            "audit" if cmd.is_none() => cmd = Some("audit"),
            other => usage(&format!("unknown argument `{other}`")),
        }
        i += 1;
    }
    match cmd {
        Some("audit") => {
            let root = root.unwrap_or_else(find_repo_root);
            std::process::exit(run_audit(&root));
        }
        _ => usage("expected a subcommand: audit"),
    }
}

fn usage(err: &str) -> ! {
    eprintln!("error: {err}");
    eprintln!("usage: cargo run -p xtask -- audit [--root <repo-root>]");
    std::process::exit(2);
}

/// Ascend from the current directory to the first one containing `rust/src`
/// (works from the repo root and from `rust/`, where cargo runs us).
fn find_repo_root() -> PathBuf {
    let mut dir = std::env::current_dir().expect("current dir");
    loop {
        if dir.join("rust").join("src").is_dir() {
            return dir;
        }
        if !dir.pop() {
            usage("could not locate the repo root (no rust/src above cwd); pass --root");
        }
    }
}

fn run_audit(root: &Path) -> i32 {
    let mut files: Vec<PathBuf> = Vec::new();
    for rel in ["rust/src", "rust/tests", "rust/benches", "examples"] {
        collect_rs(&root.join(rel), &mut files);
    }
    files.sort();
    if files.is_empty() {
        eprintln!("audit: no .rs files under {} — wrong --root?", root.display());
        return 2;
    }
    let mut findings = Vec::new();
    let mut waivers = Vec::new();
    for path in &files {
        let src = match std::fs::read_to_string(path) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("audit: cannot read {}: {e}", path.display());
                return 2;
            }
        };
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let report = lints::audit_file(&rel, &src);
        findings.extend(report.findings);
        waivers.extend(report.waivers);
    }
    for w in &waivers {
        println!("{}:{}: waived [{}] {}", w.file, w.line, w.id, w.reason);
    }
    for f in &findings {
        println!("{}:{}: [{}] {}", f.file, f.line, f.id, f.msg);
    }
    println!(
        "audit: {} files, {} finding(s), {} waiver(s)",
        files.len(),
        findings.len(),
        waivers.len()
    );
    if findings.is_empty() {
        0
    } else {
        1
    }
}

/// Recursively collect `.rs` files, skipping vendored code and this crate.
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let path = entry.path();
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if path.is_dir() {
            if name == "vendor" || name == "xtask" || name == "target" {
                continue;
            }
            collect_rs(&path, out);
        } else if name.ends_with(".rs") {
            out.push(path);
        }
    }
}
