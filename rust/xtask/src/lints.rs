//! The audit's lint catalogue (DESIGN.md §9).
//!
//! Four repo-specific lints over the scanner's per-line code/comment views:
//!
//! * [`SAFETY`] — every `unsafe` block/impl/fn carries a `// SAFETY:`
//!   comment on the same line or within the 6 lines above it.
//! * [`RELAXED`] — every `Ordering::Relaxed` in non-test library code
//!   either targets an allowlisted statistics-only counter
//!   ([`RELAXED_ALLOWLIST`]) or carries a `// relaxed:` justification
//!   within 3 lines above. Synchronization-bearing atomics must use
//!   (documented) Acquire/Release/AcqRel instead.
//! * [`NEON`] — every `#[cfg(target_arch = "aarch64")]` site in
//!   `neon/ops.rs` pairs with a `#[cfg(not(target_arch = "aarch64"))]`
//!   scalar fallback nearby and a `// parity: <test_fn>` reference naming
//!   a test that exists in the file.
//! * [`LOCK`] — in `exec/pool.rs` / `coordinator/batcher.rs`, no named
//!   `.lock()` guard is lexically live across a user-callback or enqueue
//!   boundary (`.spawn(`, `.run(`, `.join(`, `.send(`, `predict_batch(`).
//!
//! Any finding can be waived in place with
//! `// audit-waive: <lint-id> <reason>` on the same line or the line
//! above; waivers are reported (and the SAFETY lint is expected to carry
//! none — see the CI gate).

use crate::scan::{clean_lines, Line};

pub const SAFETY: &str = "safety-comment";
pub const RELAXED: &str = "relaxed-ordering";
pub const NEON: &str = "neon-parity";
pub const LOCK: &str = "lock-span";

/// Statistics-only atomic counters that may use `Ordering::Relaxed` without
/// a per-site comment. Everything here is monotone telemetry read by
/// humans/tests after synchronization elsewhere (join, channel recv, or the
/// pool mutex); none of it gates memory visibility of other data.
/// DESIGN.md §9 documents the policy; adding a name here is a code-review
/// decision, not a local convenience.
pub const RELAXED_ALLOWLIST: &[&str] = &[
    // coordinator::metrics — request/batch counters.
    "requests",
    "completed",
    "rejected",
    "shed_shutdown",
    "deadline_exceeded",
    "failed",
    "reaper_threads",
    "batches",
    "batched_instances",
    // exec::pool — claim-amortization counters.
    "claims",
    "claimed_tasks",
    // engine::early_exit — staged-scoring cost counters.
    "rows_scored",
    "trees_evaluated",
    // exec::feedback — EWMA observation counters.
    "samples",
    "replans",
    // coordinator::batcher — replan tick.
    "flushes",
    // exec::parallel — predict counter.
    "predicts",
    // obs::hist — histogram cells and min/max sketch bits.
    "buckets",
    "count",
    "min_bits",
    "max_bits",
];

/// Calls that hand control to user code or cross an enqueue/teardown
/// boundary — forbidden while a named lock guard is live ([`LOCK`]).
const LOCK_FORBIDDEN: &[&str] = &[".spawn(", ".run(", ".join(", ".send(", "predict_batch("];

/// Atomic-op tokens whose receiver names the [`RELAXED`] allowlist checks.
const ATOMIC_OPS: &[&str] = &[
    ".load(",
    ".store(",
    ".swap(",
    ".fetch_add(",
    ".fetch_sub(",
    ".fetch_max(",
    ".fetch_min(",
    ".fetch_and(",
    ".fetch_or(",
    ".fetch_xor(",
    ".compare_exchange",
];

#[derive(Debug)]
pub struct Finding {
    pub file: String,
    /// 1-based source line.
    pub line: usize,
    pub id: &'static str,
    pub msg: String,
}

#[derive(Debug)]
pub struct Waiver {
    pub file: String,
    pub line: usize,
    pub id: &'static str,
    pub reason: String,
}

#[derive(Debug, Default)]
pub struct Report {
    pub findings: Vec<Finding>,
    pub waivers: Vec<Waiver>,
}

/// Audit one file. `path` (repo-relative, `/`-separated) selects which
/// lints apply: [`SAFETY`] everywhere, [`RELAXED`] under `src/` (test code
/// — `rust/tests/`, benches, and everything at/after the file's
/// `#[cfg(test)]` — is exempt: test counters synchronize via join/recv),
/// [`NEON`] in `neon/ops.rs`, [`LOCK`] in the files whose guards cross
/// scheduler boundaries (pool, batcher, net, degrade).
pub fn audit_file(path: &str, src: &str) -> Report {
    let lines = clean_lines(src);
    let mut cands: Vec<Finding> = Vec::new();
    lint_safety(path, &lines, &mut cands);
    if path.contains("src/") && !path.contains("tests/") {
        lint_relaxed(path, &lines, &mut cands);
    }
    if path.ends_with("neon/ops.rs") {
        lint_neon(path, &lines, &mut cands);
    }
    if path.ends_with("exec/pool.rs")
        || path.ends_with("coordinator/batcher.rs")
        || path.ends_with("coordinator/net.rs")
        || path.ends_with("coordinator/degrade.rs")
    {
        lint_lock(path, &lines, &mut cands);
    }
    let mut report = Report::default();
    for f in cands {
        match waiver_reason(&lines, f.line, f.id) {
            Some(reason) => {
                report.waivers.push(Waiver { file: f.file, line: f.line, id: f.id, reason })
            }
            None => report.findings.push(f),
        }
    }
    report
}

/// `// audit-waive: <id> <reason>` on the finding's line or the line above.
fn waiver_reason(lines: &[Line], line_1based: usize, id: &str) -> Option<String> {
    let idx = line_1based - 1;
    let lo = idx.saturating_sub(1);
    for l in &lines[lo..=idx.min(lines.len() - 1)] {
        if let Some(p) = l.comment.find("audit-waive:") {
            let rest = l.comment[p + "audit-waive:".len()..].trim();
            if let Some(reason) = rest.strip_prefix(id) {
                return Some(reason.trim().to_string());
            }
        }
    }
    None
}

/// Substring match with identifier boundaries on both sides.
fn has_word(code: &str, word: &str) -> bool {
    let bytes = code.as_bytes();
    let mut from = 0;
    while let Some(p) = code[from..].find(word) {
        let start = from + p;
        let end = start + word.len();
        let pre_ok = start == 0 || !is_ident_byte(bytes[start - 1]);
        let post_ok = end == bytes.len() || !is_ident_byte(bytes[end]);
        if pre_ok && post_ok {
            return true;
        }
        from = start + 1;
    }
    false
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

fn lint_safety(path: &str, lines: &[Line], out: &mut Vec<Finding>) {
    for (idx, l) in lines.iter().enumerate() {
        if !has_word(&l.code, "unsafe") {
            continue;
        }
        let lo = idx.saturating_sub(6);
        let documented = lines[lo..=idx].iter().any(|w| w.comment.contains("SAFETY:"));
        if !documented {
            out.push(Finding {
                file: path.to_string(),
                line: idx + 1,
                id: SAFETY,
                msg: "`unsafe` without a `// SAFETY:` comment (same line or ≤ 6 lines above)"
                    .to_string(),
            });
        }
    }
}

fn lint_relaxed(path: &str, lines: &[Line], out: &mut Vec<Finding>) {
    // Everything at/after the file's `#[cfg(test)]` is test code (module
    // layout convention: test mods close the file).
    let test_start = lines
        .iter()
        .position(|l| l.code.contains("#[cfg(test)]"))
        .unwrap_or(usize::MAX);
    for (idx, l) in lines.iter().enumerate() {
        if idx >= test_start || !l.code.contains("Ordering::Relaxed") {
            continue;
        }
        let lo = idx.saturating_sub(3);
        let justified = lines[lo..=idx].iter().any(|w| w.comment.contains("relaxed:"));
        if justified {
            continue;
        }
        if let Some(recv) = atomic_receiver(lines, idx) {
            if RELAXED_ALLOWLIST.contains(&recv.as_str()) {
                continue;
            }
            out.push(Finding {
                file: path.to_string(),
                line: idx + 1,
                id: RELAXED,
                msg: format!(
                    "Ordering::Relaxed on `{recv}` — not an allowlisted statistics counter \
                     and no `// relaxed:` justification within 3 lines"
                ),
            });
        } else {
            out.push(Finding {
                file: path.to_string(),
                line: idx + 1,
                id: RELAXED,
                msg: "Ordering::Relaxed without a `// relaxed:` justification within 3 lines"
                    .to_string(),
            });
        }
    }
}

/// Receiver identifier of the nearest atomic op at/above `idx` (the same
/// line first — multi-line `compare_exchange(…)` argument lists put the
/// orderings on their own lines).
fn atomic_receiver(lines: &[Line], idx: usize) -> Option<String> {
    let lo = idx.saturating_sub(6);
    for j in (lo..=idx).rev() {
        let code = &lines[j].code;
        let mut best: Option<usize> = None;
        for op in ATOMIC_OPS {
            if let Some(p) = code.rfind(op) {
                best = Some(best.map_or(p, |b: usize| b.max(p)));
            }
        }
        if let Some(dot) = best {
            return ident_before(code, dot);
        }
    }
    None
}

/// The identifier ending just before byte position `dot` (skipping one
/// trailing `[…]`/`(…)` group, so `self.buckets[i].fetch_add` → `buckets`).
fn ident_before(code: &str, dot: usize) -> Option<String> {
    let bytes = code.as_bytes();
    let mut k = dot;
    if k > 0 && (bytes[k - 1] == b']' || bytes[k - 1] == b')') {
        let close = bytes[k - 1];
        let open = if close == b']' { b'[' } else { b'(' };
        let mut depth = 0usize;
        while k > 0 {
            k -= 1;
            if bytes[k] == close {
                depth += 1;
            } else if bytes[k] == open {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
        }
    }
    let end = k;
    while k > 0 && is_ident_byte(bytes[k - 1]) {
        k -= 1;
    }
    if k == end {
        None
    } else {
        Some(code[k..end].to_string())
    }
}

fn lint_neon(path: &str, lines: &[Line], out: &mut Vec<Finding>) {
    // Collect function names declared in this file (`fn name`).
    let mut fns: Vec<String> = Vec::new();
    for l in lines {
        let code = &l.code;
        let mut from = 0;
        while let Some(p) = code[from..].find("fn ") {
            let start = from + p;
            let pre_ok = start == 0 || !is_ident_byte(code.as_bytes()[start - 1]);
            if pre_ok {
                let rest = &code[start + 3..];
                let name: String =
                    rest.chars().take_while(|&c| c.is_ascii_alphanumeric() || c == '_').collect();
                if !name.is_empty() {
                    fns.push(name);
                }
            }
            from = start + 3;
        }
    }
    let is_pos_cfg = |l: &Line| {
        l.raw.contains("target_arch = \"aarch64\"")
            && !l.raw.contains("not(target_arch")
            // Test-gated aarch64 code IS the parity test — exempt.
            && !l.raw.contains("all(test")
            && l.code.contains("target_arch")
    };
    for (idx, l) in lines.iter().enumerate() {
        if !is_pos_cfg(l) {
            continue;
        }
        // A paired scalar fallback within ±60 lines.
        let lo = idx.saturating_sub(60);
        let hi = (idx + 60).min(lines.len() - 1);
        let fallback = lines[lo..=hi]
            .iter()
            .any(|w| w.raw.contains("not(target_arch = \"aarch64\")") && w.code.contains("not("));
        // A `// parity: <fn>` reference within ±10 lines naming a test
        // that exists in this file.
        let plo = idx.saturating_sub(10);
        let phi = (idx + 10).min(lines.len() - 1);
        let mut parity_named: Option<String> = None;
        let mut parity_ok = false;
        for w in &lines[plo..=phi] {
            if let Some(p) = w.comment.find("parity:") {
                let name: String = w.comment[p + "parity:".len()..]
                    .trim_start()
                    .chars()
                    .take_while(|&c| c.is_ascii_alphanumeric() || c == '_')
                    .collect();
                if !name.is_empty() {
                    parity_ok |= fns.contains(&name);
                    parity_named = Some(name);
                }
            }
        }
        if fallback && parity_ok {
            continue;
        }
        let mut missing = Vec::new();
        if !fallback {
            missing.push("a `#[cfg(not(target_arch = \"aarch64\"))]` scalar fallback".to_string());
        }
        if !parity_ok {
            missing.push(match parity_named {
                Some(n) => format!("`// parity:` names `{n}` but no such fn exists here"),
                None => "a `// parity: <test_fn>` reference within 10 lines".to_string(),
            });
        }
        out.push(Finding {
            file: path.to_string(),
            line: idx + 1,
            id: NEON,
            msg: format!("aarch64 intrinsic path missing {}", missing.join(" and ")),
        });
    }
}

fn lint_lock(path: &str, lines: &[Line], out: &mut Vec<Finding>) {
    // Named guards: (binding, scope depth at declaration).
    let mut guards: Vec<(String, i64)> = Vec::new();
    let mut depth: i64 = 0;
    for (idx, l) in lines.iter().enumerate() {
        let code = &l.code;
        // New guard? `let [mut] name = …lock()…` on one line, unless the
        // initializer *derefs* the temporary guard (`= *…lock()…` copies a
        // value out; the guard dies at the semicolon).
        if let Some(name) = guard_binding(code) {
            guards.retain(|(g, _)| g != &name);
            guards.push((name, depth + open_delta(code).max(0)));
        }
        // Forbidden boundary calls while any guard is live.
        if !guards.is_empty() {
            for tok in LOCK_FORBIDDEN {
                if code.contains(tok) {
                    let held: Vec<&str> =
                        guards.iter().map(|(g, _)| g.as_str()).collect();
                    out.push(Finding {
                        file: path.to_string(),
                        line: idx + 1,
                        id: LOCK,
                        msg: format!(
                            "`{}` reached while lock guard(s) [{}] are live — \
                             drop or scope the guard first",
                            tok.trim_start_matches('.').trim_end_matches('('),
                            held.join(", ")
                        ),
                    });
                    break;
                }
            }
        }
        // Explicit drops end a guard's span.
        for (g, _) in guards.clone() {
            if code.contains(&format!("drop({g})")) {
                guards.retain(|(n, _)| n != &g);
            }
        }
        // Scope tracking: guards die when their block closes.
        let (min_depth, end_depth) = walk_depth(code, depth);
        guards.retain(|(_, d)| min_depth >= *d);
        depth = end_depth;
    }
}

/// `Some(binding)` when `code` declares a lock guard.
fn guard_binding(code: &str) -> Option<String> {
    let lp = code.find("let ")?;
    let lock_p = code.find(".lock()")?;
    if lock_p < lp {
        return None;
    }
    let mut rest = code[lp + 4..].trim_start();
    rest = rest.strip_prefix("mut ").unwrap_or(rest).trim_start();
    let name: String =
        rest.chars().take_while(|&c| c.is_ascii_alphanumeric() || c == '_').collect();
    if name.is_empty() {
        return None;
    }
    // `let v = *m.lock().unwrap();` copies the value; no guard outlives
    // the statement.
    if let Some(eq) = code.find('=') {
        if code[eq + 1..].trim_start().starts_with('*') {
            return None;
        }
    }
    Some(name)
}

/// Net `{`/`}` delta of a line (for the declaration depth of a guard whose
/// own line opens a block).
fn open_delta(code: &str) -> i64 {
    let opens = code.matches('{').count() as i64;
    let closes = code.matches('}').count() as i64;
    opens - closes
}

/// Walk a line's braces: returns (minimum depth reached, depth at end).
fn walk_depth(code: &str, start: i64) -> (i64, i64) {
    let mut d = start;
    let mut min = start;
    for c in code.chars() {
        match c {
            '{' => d += 1,
            '}' => {
                d -= 1;
                min = min.min(d);
            }
            _ => {}
        }
    }
    (min, d)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ids(r: &Report) -> Vec<&'static str> {
        r.findings.iter().map(|f| f.id).collect()
    }

    // ---- safety-comment -------------------------------------------------

    #[test]
    fn safety_fires_on_undocumented_unsafe() {
        let src = "pub fn f(p: *mut u8) {\n    unsafe { *p = 1 };\n}\n";
        let r = audit_file("src/x.rs", src);
        assert_eq!(ids(&r), vec![SAFETY]);
        assert_eq!(r.findings[0].line, 2);
    }

    #[test]
    fn safety_accepts_documented_unsafe() {
        let src = "pub fn f(p: *mut u8) {\n    // SAFETY: p is valid and exclusive\n    unsafe { *p = 1 };\n}\n";
        let r = audit_file("src/x.rs", src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
    }

    #[test]
    fn safety_accepts_comment_within_window() {
        // One intervening code line between comment and the unsafe block —
        // the batcher's `out_ptr` pattern.
        let src = "// SAFETY: disjoint ranges, buffer outlives tasks\nlet xs = &x[a..b];\nlet os = unsafe { std::slice::from_raw_parts_mut(p, n) };\n";
        let r = audit_file("src/x.rs", src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
    }

    #[test]
    fn safety_ignores_unsafe_in_strings_and_comments() {
        let src = "// this fn is not unsafe at all\nlet s = \"unsafe\";\n";
        let r = audit_file("src/x.rs", src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
    }

    #[test]
    fn safety_waiver_is_reported_not_failed() {
        let src = "// audit-waive: safety-comment legacy site, tracked in #42\nunsafe { ffi() };\n";
        let r = audit_file("src/x.rs", src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
        assert_eq!(r.waivers.len(), 1);
        assert_eq!(r.waivers[0].id, SAFETY);
        assert!(r.waivers[0].reason.contains("legacy"));
    }

    // ---- relaxed-ordering -----------------------------------------------

    #[test]
    fn relaxed_fires_on_unjustified_non_allowlisted_site() {
        let src = "fn f(flag: &AtomicBool) {\n    flag.store(true, Ordering::Relaxed);\n}\n";
        let r = audit_file("src/x.rs", src);
        assert_eq!(ids(&r), vec![RELAXED]);
        assert!(r.findings[0].msg.contains("flag"));
    }

    #[test]
    fn relaxed_accepts_justification_comment() {
        let src = "fn f(flag: &AtomicBool) {\n    // relaxed: telemetry only; readers tolerate staleness\n    flag.store(true, Ordering::Relaxed);\n}\n";
        let r = audit_file("src/x.rs", src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
    }

    #[test]
    fn relaxed_accepts_allowlisted_counter() {
        let src = "fn f(m: &Metrics) {\n    m.claims.fetch_add(1, Ordering::Relaxed);\n}\n";
        let r = audit_file("src/x.rs", src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
    }

    #[test]
    fn relaxed_accepts_early_exit_cost_counters() {
        // The `engine::early_exit` staged-scoring counters (ISSUE 9): pure
        // monotone telemetry read back as deltas by Feedback::record_trees,
        // so Relaxed is correct and the names ride the allowlist — the tree
        // must stay at 0 findings / 0 waivers when they land.
        let src = "fn f(&self, rows: u64, trees: u64) {\n    \
                   self.rows_scored.fetch_add(rows, Ordering::Relaxed);\n    \
                   self.trees_evaluated.fetch_add(trees, Ordering::Relaxed);\n}\n";
        let r = audit_file("src/engine/early_exit.rs", src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
        assert!(r.waivers.is_empty(), "{:?}", r.waivers);
    }

    #[test]
    fn relaxed_resolves_indexed_receiver() {
        let src = "fn f(&self) {\n    self.buckets[idx(v)].fetch_add(1, Ordering::Relaxed);\n}\n";
        let r = audit_file("src/x.rs", src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
    }

    #[test]
    fn relaxed_resolves_multiline_compare_exchange() {
        let src = "fn f(&self) {\n    let _ = self.min_bits.compare_exchange_weak(\n        cur,\n        v,\n        Ordering::Relaxed,\n        Ordering::Relaxed,\n    );\n}\n";
        let r = audit_file("src/x.rs", src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
    }

    #[test]
    fn relaxed_exempts_test_code_and_test_files() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f(x: &AtomicU64) { x.store(1, Ordering::Relaxed); }\n}\n";
        assert!(audit_file("src/x.rs", src).findings.is_empty());
        let src2 = "fn f(x: &AtomicU64) { x.store(1, Ordering::Relaxed); }\n";
        assert!(audit_file("rust/tests/x.rs", src2).findings.is_empty());
    }

    #[test]
    fn relaxed_waiver_is_reported() {
        let src = "fn f(x: &AtomicU64) {\n    // audit-waive: relaxed-ordering migration pending\n    x.store(1, Ordering::Relaxed);\n}\n";
        let r = audit_file("src/x.rs", src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
        assert_eq!(r.waivers.len(), 1);
    }

    // ---- neon-parity ----------------------------------------------------

    #[test]
    fn neon_fires_without_fallback_or_parity() {
        let src = "pub fn vadd(a: A, b: A) -> A {\n    #[cfg(target_arch = \"aarch64\")]\n    return native(a, b);\n    scalar(a, b)\n}\n";
        let r = audit_file("src/neon/ops.rs", src);
        assert_eq!(ids(&r), vec![NEON]);
        assert!(r.findings[0].msg.contains("fallback"));
    }

    #[test]
    fn neon_accepts_paired_fallback_with_parity_test() {
        let src = "pub fn vadd(a: A, b: A) -> A {\n    // parity: vadd_native_matches_scalar\n    #[cfg(target_arch = \"aarch64\")]\n    return vadd_native(a, b);\n    #[cfg(not(target_arch = \"aarch64\"))]\n    vadd_scalar(a, b)\n}\nfn vadd_scalar(a: A, b: A) -> A { a }\nfn vadd_native_matches_scalar() {}\n";
        let r = audit_file("src/neon/ops.rs", src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
    }

    #[test]
    fn neon_accepts_dispatcher_plus_separate_native_fn() {
        // The `vcgtq_s32` FLInt-carrier shape: a sim-default dispatcher
        // (whose `not()` branch is the fallback) plus a standalone
        // `#[cfg(target_arch)]` native fn carrying its own `// parity:`
        // line — TWO positive-cfg sites, both satisfied by the one
        // fallback within ±60 lines and the named test.
        let src = "pub fn vcgt(a: A, b: A) -> M {\n    \
                   // parity: native_cmgt_matches_sim\n    \
                   #[cfg(target_arch = \"aarch64\")]\n    \
                   return vcgt_native(a, b);\n    \
                   #[cfg(not(target_arch = \"aarch64\"))]\n    \
                   vcgt_sim(a, b)\n}\n\
                   pub fn vcgt_sim(a: A, b: A) -> M { m }\n\
                   // parity: native_cmgt_matches_sim\n\
                   #[cfg(target_arch = \"aarch64\")]\n\
                   fn vcgt_native(a: A, b: A) -> M { m }\n\
                   fn native_cmgt_matches_sim() {}\n";
        let r = audit_file("src/neon/ops.rs", src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
    }

    #[test]
    fn neon_rejects_dangling_parity_reference() {
        let src = "// parity: no_such_test\n#[cfg(target_arch = \"aarch64\")]\nreturn native(a, b);\n#[cfg(not(target_arch = \"aarch64\"))]\nscalar(a, b)\n";
        let r = audit_file("src/neon/ops.rs", src);
        assert_eq!(ids(&r), vec![NEON]);
        assert!(r.findings[0].msg.contains("no_such_test"));
    }

    #[test]
    fn neon_ignores_doc_comment_mentions() {
        let src = "//! Mentions #[cfg(target_arch = \"aarch64\")] in prose only.\nfn f() {}\n";
        let r = audit_file("src/neon/ops.rs", src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
    }

    #[test]
    fn neon_exempts_test_gated_modules() {
        // The parity-test module's own gate is not an intrinsic path.
        let src = "#[cfg(all(test, target_arch = \"aarch64\"))]\nmod parity_tests {}\n";
        let r = audit_file("src/neon/ops.rs", src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
    }

    // ---- lock-span ------------------------------------------------------

    #[test]
    fn lock_fires_on_send_under_live_guard() {
        let src = "fn f(&self) {\n    let states = self.states.lock().unwrap();\n    for r in states.iter() {\n        r.reply.send(1).unwrap();\n    }\n}\n";
        let r = audit_file("src/exec/pool.rs", src);
        assert_eq!(ids(&r), vec![LOCK]);
        assert!(r.findings[0].msg.contains("states"));
    }

    #[test]
    fn lock_accepts_scoped_guard() {
        let src = "fn f(&self) {\n    let planned = {\n        let weights = self.weights.lock().unwrap();\n        plan(&weights)\n    };\n    self.client.spawn(planned);\n}\n";
        let r = audit_file("src/exec/pool.rs", src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
    }

    #[test]
    fn lock_accepts_explicit_drop() {
        let src = "fn f(&self) {\n    let guard = self.state.lock().unwrap();\n    self.wakeup.notify_all();\n    drop(guard);\n    for w in self.workers.drain(..) {\n        let _ = w.join();\n    }\n}\n";
        let r = audit_file("src/exec/pool.rs", src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
    }

    #[test]
    fn lock_ignores_deref_copies() {
        let src = "fn f(&self) {\n    let t0 = *self.exec_start.lock().unwrap();\n    self.reply.send(t0).unwrap();\n}\n";
        let r = audit_file("src/exec/pool.rs", src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
    }

    #[test]
    fn lock_waiver_is_reported() {
        let src = "fn f(&self) {\n    let g = self.m.lock().unwrap();\n    // audit-waive: lock-span send is non-blocking here\n    self.tx.send(1).unwrap();\n}\n";
        let r = audit_file("src/coordinator/batcher.rs", src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
        assert_eq!(r.waivers.len(), 1);
        assert_eq!(r.waivers[0].id, LOCK);
    }

    #[test]
    fn lock_only_applies_to_scheduler_files() {
        let src = "fn f(&self) {\n    let g = self.m.lock().unwrap();\n    self.tx.send(1).unwrap();\n}\n";
        assert!(audit_file("src/obs/span.rs", src).findings.is_empty());
    }
}
