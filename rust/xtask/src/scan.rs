//! Minimal Rust source scanner for the audit lints.
//!
//! Splits each source line into its **code** part (string-literal contents
//! blanked, comments stripped) and its **comment** text, tracking the state
//! that spans lines: multi-line string literals, raw strings (`r"…"`,
//! `r#"…"#`, byte variants), and nested block comments. This is a token
//! heuristic, not a parser — it only has to be right enough that
//! `Ordering::Relaxed` inside a log message is not a lint site and
//! `// SAFETY:` inside a string is not a justification. Lints that must see
//! string literals (the `cfg(target_arch = "aarch64")` attribute) use the
//! preserved `raw` line alongside `code`.

/// One source line, split by [`clean_lines`].
#[derive(Debug, Default, Clone)]
pub struct Line {
    /// The line as written.
    pub raw: String,
    /// Code outside comments, with string/char literal contents removed
    /// (the delimiting quotes are kept so token shapes survive).
    pub code: String,
    /// Comment text (`//…` and block-comment interiors) on this line.
    pub comment: String,
}

enum State {
    Code,
    /// Inside a normal (or byte) string literal.
    Str,
    /// Inside a raw string whose closing quote needs this many `#`s.
    RawStr(usize),
    /// Inside block comments, nested this deep.
    Block(usize),
}

/// Scan `src` into per-line code/comment views (see module docs).
pub fn clean_lines(src: &str) -> Vec<Line> {
    let mut out = Vec::new();
    let mut state = State::Code;
    for raw in src.lines() {
        let chars: Vec<char> = raw.chars().collect();
        let mut code = String::new();
        let mut comment = String::new();
        let mut i = 0;
        while i < chars.len() {
            match state {
                State::Code => {
                    let c = chars[i];
                    let next = chars.get(i + 1).copied();
                    if c == '/' && next == Some('/') {
                        comment.extend(chars[i..].iter());
                        i = chars.len();
                    } else if c == '/' && next == Some('*') {
                        state = State::Block(1);
                        i += 2;
                    } else if let Some((hashes, past_quote)) = raw_string_open(&chars, i) {
                        code.push('"');
                        state = State::RawStr(hashes);
                        i = past_quote;
                    } else if c == 'b' && next == Some('"') {
                        code.push('"');
                        state = State::Str;
                        i += 2;
                    } else if c == '"' {
                        code.push('"');
                        state = State::Str;
                        i += 1;
                    } else if c == '\'' {
                        if next == Some('\\') {
                            // Escaped char literal ('\n', '\'', '\u{…}'):
                            // skip the escaped char, then find the closing
                            // quote.
                            let mut j = i + 3;
                            while j < chars.len() && chars[j] != '\'' && j < i + 14 {
                                j += 1;
                            }
                            i = (j + 1).min(chars.len());
                        } else if chars.get(i + 2) == Some(&'\'') {
                            // Plain char literal, including '"' and '{'.
                            i += 3;
                        } else {
                            // Lifetime ('a, 'static): keep the tick.
                            code.push('\'');
                            i += 1;
                        }
                    } else {
                        code.push(c);
                        i += 1;
                    }
                }
                State::Str => {
                    let c = chars[i];
                    if c == '\\' {
                        i += 2; // skip the escaped char (may run past EOL)
                    } else if c == '"' {
                        code.push('"');
                        state = State::Code;
                        i += 1;
                    } else {
                        i += 1;
                    }
                }
                State::RawStr(h) => {
                    if chars[i] == '"' && count_hashes(&chars, i + 1) >= h {
                        code.push('"');
                        state = State::Code;
                        i += 1 + h;
                    } else {
                        i += 1;
                    }
                }
                State::Block(depth) => {
                    let c = chars[i];
                    let next = chars.get(i + 1).copied();
                    if c == '*' && next == Some('/') {
                        state = if depth == 1 { State::Code } else { State::Block(depth - 1) };
                        i += 2;
                    } else if c == '/' && next == Some('*') {
                        state = State::Block(depth + 1);
                        i += 2;
                    } else {
                        comment.push(c);
                        i += 1;
                    }
                }
            }
        }
        out.push(Line { raw: raw.to_string(), code, comment });
    }
    out
}

/// If `chars[i..]` opens a raw string literal (`r"`, `r#"`, `br##"`, …),
/// return `(hash count, index just past the opening quote)`.
fn raw_string_open(chars: &[char], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if chars.get(j) == Some(&'b') {
        j += 1;
    }
    if chars.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let hashes = count_hashes(chars, j);
    j += hashes;
    if chars.get(j) == Some(&'"') {
        Some((hashes, j + 1))
    } else {
        None
    }
}

fn count_hashes(chars: &[char], from: usize) -> usize {
    chars[from.min(chars.len())..].iter().take_while(|&&c| c == '#').count()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strips_line_comments_into_comment_field() {
        let l = clean_lines("let x = 1; // SAFETY: not really code");
        assert_eq!(l[0].code.trim(), "let x = 1;");
        assert!(l[0].comment.contains("SAFETY:"));
    }

    #[test]
    fn blanks_string_contents() {
        let l = clean_lines(r#"panic!("uses Ordering::Relaxed in text");"#);
        assert!(!l[0].code.contains("Relaxed"));
        assert!(l[0].code.contains("panic!"));
        assert!(l[0].raw.contains("Relaxed"));
    }

    #[test]
    fn tracks_multiline_strings() {
        let src = "let s = \"first\nOrdering::Relaxed still in string\";\nlet y = 2;";
        let l = clean_lines(src);
        assert!(!l[1].code.contains("Relaxed"));
        assert_eq!(l[2].code.trim(), "let y = 2;");
    }

    #[test]
    fn handles_raw_strings_and_hashes() {
        let src = "let s = r#\"json \"quoted\" body\"#; let t = 3;";
        let l = clean_lines(src);
        assert!(l[0].code.contains("let t = 3;"));
        assert!(!l[0].code.contains("quoted"));
    }

    #[test]
    fn char_literals_do_not_open_strings() {
        let src = "if c == b'\"' { x = '\\''; } let z = 'a'; // tail";
        let l = clean_lines(src);
        assert!(l[0].code.contains("let z ="));
        assert!(l[0].comment.contains("tail"));
    }

    #[test]
    fn lifetimes_survive() {
        let l = clean_lines("fn f<'a>(x: &'a str) -> &'a str { x }");
        assert!(l[0].code.contains("<'a>"));
    }

    #[test]
    fn nested_block_comments() {
        let src = "a /* outer /* inner */ still comment */ b";
        let l = clean_lines(src);
        assert_eq!(l[0].code.replace(' ', ""), "ab");
        assert!(l[0].comment.contains("inner"));
    }
}
