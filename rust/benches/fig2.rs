//! Regenerates paper Figure 2 (critical-difference diagrams per device).
fn main() {
    let scale = arbors::bench::harness::Scale::from_env();
    let text = arbors::bench::experiments::fig2(&scale);
    arbors::bench::experiments::archive("fig2", &text);
    println!("{text}");
}
