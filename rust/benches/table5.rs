//! Regenerates paper Table 5 (classification runtimes, 10 engine variants)
//! for L=64 (main text) and L=32 (appendix).
fn main() {
    let scale = arbors::bench::harness::Scale::from_env();
    let t64 = arbors::bench::experiments::table5(&scale, 64);
    arbors::bench::experiments::archive("table5", &t64);
    println!("{t64}");
    let t32 = arbors::bench::experiments::table5(&scale, 32);
    arbors::bench::experiments::archive("table5_l32", &t32);
    println!("{t32}");
}
