//! Extra: thread-scaling of the exec runtime (row-sharded ParallelEngine)
//! across engines × forest shapes. Threads via ARBORS_THREADS (default 4);
//! scale via ARBORS_SCALE. JSON lands in results/scaling.json.
fn main() {
    let scale = arbors::bench::harness::Scale::from_env();
    let threads = std::env::var("ARBORS_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let text = arbors::bench::experiments::scaling(&scale, threads, None, false);
    arbors::bench::experiments::archive("scaling", &text);
    println!("{text}");
}
