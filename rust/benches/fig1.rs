//! Regenerates paper Figure 1 (mean speedup vs NA over tree counts).
fn main() {
    let scale = arbors::bench::harness::Scale::from_env();
    let text = arbors::bench::experiments::fig1(&scale);
    arbors::bench::experiments::archive("fig1", &text);
    println!("{text}");
}
