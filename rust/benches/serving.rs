//! Extra: serving-path benchmark — two deployments (i16 + i8) under
//! concurrent clients, server-shared pool vs one pool per deployment.
//! Threads via ARBORS_THREADS (default 4); scale via ARBORS_SCALE.
//! JSON lands in results/serving.json.
fn main() {
    let scale = arbors::bench::harness::Scale::from_env();
    let threads = std::env::var("ARBORS_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let text = arbors::bench::experiments::serving(&scale, threads);
    arbors::bench::experiments::archive("serving", &text);
    println!("{text}");
}
