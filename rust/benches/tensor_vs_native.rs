//! Extra: native Rust engines vs the AOT JAX/Pallas tensor path (PJRT).
//! Requires `make artifacts`.
fn main() {
    let scale = arbors::bench::harness::Scale::from_env();
    match arbors::bench::experiments::tensor_vs_native(scale.repeats) {
        Ok(text) => {
            arbors::bench::experiments::archive("tensor_vs_native", &text);
            println!("{text}");
        }
        Err(e) => eprintln!("skipped: {e:#}"),
    }
}
