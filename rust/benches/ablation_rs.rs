//! Extra: RapidScorer design ablation (node merging on/off vs VQS/QS).
fn main() {
    let scale = arbors::bench::harness::Scale::from_env();
    let text = arbors::bench::experiments::ablation_rs(&scale);
    arbors::bench::experiments::archive("ablation_rs", &text);
    println!("{text}");
}
