//! Regenerates paper Table 4 (% unique nodes after RapidScorer merging).
fn main() {
    let scale = arbors::bench::harness::Scale::from_env();
    let text = arbors::bench::experiments::table4(&scale);
    arbors::bench::experiments::archive("table4", &text);
    println!("{text}");
}
