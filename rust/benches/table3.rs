//! Regenerates paper Table 3 (accuracy under fixed-point quantization).
fn main() {
    let scale = arbors::bench::harness::Scale::from_env();
    let text = arbors::bench::experiments::table3(&scale);
    arbors::bench::experiments::archive("table3", &text);
    println!("{text}");
}
