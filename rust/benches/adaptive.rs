//! Extra: the adaptive-execution grid — static/adaptive shard plans ×
//! pinned/unpinned workers × claim-1/claim-k batch claiming on a synthetic
//! big.LITTLE topology (ISSUE 5). Threads via ARBORS_THREADS (default 4);
//! scale via ARBORS_SCALE; ARBORS_SMOKE=1 shrinks the grid for CI. JSON
//! lands in results/adaptive.json.
fn main() {
    let scale = arbors::bench::harness::Scale::from_env();
    let threads = std::env::var("ARBORS_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let smoke = std::env::var("ARBORS_SMOKE").is_ok_and(|v| v == "1");
    let text = arbors::bench::experiments::adaptive(&scale, threads, smoke);
    arbors::bench::experiments::archive("adaptive", &text);
    println!("{text}");
}
