//! Extra: resident model size per engine + energy-per-inference estimates.
fn main() {
    let scale = arbors::bench::harness::Scale::from_env();
    let text = arbors::bench::experiments::memory_energy(&scale);
    arbors::bench::experiments::archive("memory_energy", &text);
    println!("{text}");
}
