//! Microbenchmark: µs/instance per engine over batch sizes — the profiling
//! entry point for the §Perf optimization loop.
use arbors::bench::harness::{build_engine_arc, cached_rf, eval_batch, time_per_instance, Scale};
use arbors::data::DatasetId;
use arbors::engine::{all_variants, variant_name};

fn main() {
    let scale = Scale::from_env();
    let ds = DatasetId::Magic.generate(DatasetId::Magic.default_n(), 0xD5 ^ 64);
    let (train, _) = ds.split(0.2, 7);
    let f = cached_rf(&train, scale.cls_trees, 64);
    let mut out = String::new();
    out.push_str(&format!(
        "engine micro (magic, {} trees x 64 leaves), host µs/instance\n\n{:<8}",
        scale.cls_trees, "batch"
    ));
    let variants = all_variants();
    for &(k, p) in &variants {
        out.push_str(&format!("{:>9}", variant_name(k, p)));
    }
    out.push('\n');
    for batch in [1usize, 4, 16, 64, 256, 1024] {
        let x = eval_batch(&ds, batch);
        out.push_str(&format!("{batch:<8}"));
        for &(k, p) in &variants {
            match build_engine_arc(k, p, &f) {
                Some(e) => out.push_str(&format!("{:>9.2}", time_per_instance(e.as_ref(), &x, scale.repeats))),
                None => out.push_str(&format!("{:>9}", "-")),
            }
        }
        out.push('\n');
    }
    arbors::bench::experiments::archive("engine_micro", &out);
    println!("{out}");
}
