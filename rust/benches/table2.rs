//! Regenerates paper Table 2 (ranking runtimes). `ARBORS_SCALE=full` for
//! paper-scale forests.
fn main() {
    let scale = arbors::bench::harness::Scale::from_env();
    let text = arbors::bench::experiments::table2(&scale);
    arbors::bench::experiments::archive("table2", &text);
    println!("{text}");
}
