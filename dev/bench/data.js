window.BENCHMARK_DATA = {
  "entries": {},
  "lastUpdate": 0,
  "repoUrl": ""
}
