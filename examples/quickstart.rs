//! Quickstart: train a Random Forest on a synthetic dataset, build every
//! inference engine, check they all agree with the reference traversal, and
//! compare their speed.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use arbors::bench::harness::{eval_batch, time_per_instance};
use arbors::data::DatasetId;
use arbors::engine::{all_variants_with_i8, build, variant_name};
use arbors::forest::builder::{train_random_forest, RfParams, TreeParams};
use arbors::forest::Forest;
use arbors::quant::{choose_scale, choose_scale_i8, QForest};

fn main() -> anyhow::Result<()> {
    // 1. Data: a Magic04-like synthetic classification problem.
    let ds = DatasetId::Magic.generate(4000, 42);
    let (train, test) = ds.split(0.2, 7);
    println!(
        "dataset: {} ({} train / {} test, d={}, C={})",
        ds.name, train.n, test.n, ds.d, ds.n_classes
    );

    // 2. Train a Random Forest (128 trees, <=32 leaves — a QuickScorer-
    //    friendly shape).
    let forest = train_random_forest(
        &train.x,
        &train.labels,
        train.d,
        train.n_classes,
        RfParams {
            n_trees: 128,
            tree: TreeParams { max_leaves: 32, min_samples_leaf: 2, mtry: 0 },
            ..Default::default()
        },
    );
    println!(
        "forest: {} trees, {} nodes, accuracy {:.2}%",
        forest.n_trees(),
        forest.n_nodes(),
        100.0 * forest.accuracy(&test.x, &test.labels)
    );

    // 3. Build every engine variant and verify agreement with the reference.
    let x = eval_batch(&test, 512);
    let want_float = forest.predict_batch(&x);
    let want_argmax = Forest::argmax(&want_float, forest.n_classes);
    // Overflow-safe scale (§5): the i16 engines' SIMD accumulators must
    // not wrap on any instance.
    let cfg = choose_scale(&forest, 1.0);
    let qf = QForest::from_forest(&forest, cfg);
    let want_quant = qf.predict_batch(&x);
    // The int8 tier chooses its own (8-bit) scale — see quant docs.
    let qf8 = QForest::<i8>::from_forest(&forest, choose_scale_i8(&forest, 1.0));
    let want_quant8 = qf8.predict_batch(&x);

    println!("\n{:<7} {:>12} {:>9}  agreement", "engine", "µs/inst", "speedup");
    // Measure the NA baseline first so every row can report its speedup.
    let na = build(arbors::engine::EngineKind::Naive, arbors::engine::Precision::F32, &forest, None)?;
    let na_time = time_per_instance(na.as_ref(), &x, 3);
    for (kind, precision) in all_variants_with_i8() {
        // The i16-typed config only carries the scale for the i16 tier;
        // the i8 tier picks its own, so pass None there.
        let quant = match precision {
            arbors::engine::Precision::I16 => Some(cfg),
            _ => None,
        };
        let engine = build(kind, precision, &forest, quant)?;
        let got = engine.predict(&x);
        // Each tier must match its own naive reference.
        let reference = match precision {
            arbors::engine::Precision::F32 => &want_float,
            arbors::engine::Precision::I16 => &want_quant,
            arbors::engine::Precision::I8 => &want_quant8,
        };
        let max_diff = got
            .iter()
            .zip(reference)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        let argmax_ok = Forest::argmax(&got, forest.n_classes) == want_argmax;
        let t = time_per_instance(engine.as_ref(), &x, 3);
        println!(
            "{:<7} {:>12.2} {:>8.1}x  max|Δ|={max_diff:.1e} argmax={}",
            variant_name(kind, precision),
            t,
            na_time / t,
            if argmax_ok { "OK" } else { "differs (quantization error)" },
        );
    }

    println!("\nAll engines agree with their reference traversal.");
    Ok(())
}
