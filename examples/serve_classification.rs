//! End-to-end serving driver (the repo's E2E validation run; results are
//! recorded in EXPERIMENTS.md §E2E).
//!
//! Trains a real Random Forest on the Magic-like dataset, auto-selects the
//! best engine, deploys it behind the coordinator's dynamic batcher, and
//! drives it with concurrent open-loop clients. Reports throughput, latency
//! percentiles, achieved batch sizes, and model accuracy over the served
//! traffic.
//!
//! ```sh
//! cargo run --release --example serve_classification [-- <requests> <clients>]
//! ```

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use arbors::coordinator::{BatchConfig, Server};
use arbors::data::DatasetId;
use arbors::forest::builder::{train_random_forest, RfParams, TreeParams};
use arbors::forest::Forest;
use arbors::util::Stopwatch;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n_requests: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(50_000);
    let n_clients: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(4);

    // --- model ----------------------------------------------------------
    let ds = DatasetId::Magic.generate(6000, 42);
    let (train, test) = ds.split(0.2, 7);
    eprintln!("training RF 256x64 on {} ({} rows)...", train.name, train.n);
    let forest = train_random_forest(
        &train.x,
        &train.labels,
        train.d,
        train.n_classes,
        RfParams {
            n_trees: 256,
            tree: TreeParams { max_leaves: 64, min_samples_leaf: 2, mtry: 0 },
            ..Default::default()
        },
    );
    eprintln!(
        "model accuracy (offline): {:.2}%",
        100.0 * forest.accuracy(&test.x, &test.labels)
    );

    // --- deploy with auto-selected engine --------------------------------
    let server = Arc::new(Server::new());
    let sel = server.deploy_auto(
        "magic",
        &forest,
        &test.x[..test.d * 512],
        BatchConfig {
            max_batch: 128,
            max_delay: std::time::Duration::from_micros(200),
            queue_cap: 65_536,
            // Deprecated alias for exec_threads (the pre-fusion batcher's
            // private predict workers); folded into the thread budget.
            workers: 1,
            // Let the selector weigh threaded candidates (e.g. RS×4t) and
            // register the winner's budget on the server-shared pool.
            exec_threads: 4,
            drain_timeout: None,
            adaptive: true,
        },
    )?;
    eprint!("{}", sel.report());
    eprintln!("deployed with engine: {}\n", sel.best().name);

    // --- drive ------------------------------------------------------------
    let correct = Arc::new(AtomicUsize::new(0));
    let errors = Arc::new(AtomicUsize::new(0));
    let test = Arc::new(test);
    let sw = Stopwatch::start();
    let mut clients = Vec::new();
    for cid in 0..n_clients {
        let server = server.clone();
        let test = test.clone();
        let correct = correct.clone();
        let errors = errors.clone();
        clients.push(std::thread::spawn(move || {
            let dep = server.model("magic").unwrap();
            let per_client = n_requests / n_clients;
            let mut inflight = Vec::with_capacity(256);
            for r in 0..per_client {
                let i = (cid + r * n_clients) % test.n;
                match dep.batcher.submit(test.row(i).to_vec()) {
                    Ok(rx) => inflight.push((i, rx)),
                    Err(_) => {
                        errors.fetch_add(1, Ordering::Relaxed);
                    }
                }
                if inflight.len() >= 256 || r + 1 == per_client {
                    for (i, rx) in inflight.drain(..) {
                        match rx.recv() {
                            Ok(Ok(scores)) => {
                                let pred =
                                    Forest::argmax(&scores, test.n_classes)[0];
                                if pred == test.labels[i] {
                                    correct.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                            _ => {
                                errors.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                }
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    let elapsed_s = sw.micros() / 1e6;

    // --- report -----------------------------------------------------------
    let dep = server.model("magic").unwrap();
    let m = &dep.batcher.metrics;
    let lat = m.latency_summary();
    let done = m.completed.load(Ordering::Relaxed);
    println!("=== serve_classification E2E ===");
    println!("engine:            {}", dep.engine_name);
    println!("requests:          {n_requests} via {n_clients} clients");
    println!("completed:         {done} (errors/rejected: {})", errors.load(Ordering::Relaxed));
    println!("wall time:         {elapsed_s:.2} s");
    println!("throughput:        {:.0} req/s", done as f64 / elapsed_s);
    println!(
        "latency µs:        p50={:.0} p95={:.0} p99={:.0} max={:.0}",
        lat.median, lat.p95, lat.p99, lat.max
    );
    println!(
        "batching:          {} batches, mean size {:.1}",
        m.batches.load(Ordering::Relaxed),
        m.mean_batch_size()
    );
    println!(
        "served accuracy:   {:.2}%",
        100.0 * correct.load(Ordering::Relaxed) as f64 / done.max(1) as f64
    );
    Ok(())
}
