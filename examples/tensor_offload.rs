//! Tensor offload: serve a forest through the AOT JAX/Pallas → HLO → PJRT
//! path and cross-check it against the native Rust engines.
//!
//! Requires `make artifacts` (Python runs once at build time; this binary
//! never invokes Python).
//!
//! ```sh
//! make artifacts && cargo run --release --example tensor_offload
//! ```

use std::path::PathBuf;

use arbors::bench::harness::time_per_instance;
use arbors::engine::tensor::TensorEngine;
use arbors::engine::{build, Engine, EngineKind, Precision};
use arbors::forest::io;
use arbors::runtime::load_manifest;
use arbors::util::Pcg32;

fn main() -> anyhow::Result<()> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    anyhow::ensure!(
        dir.join("manifest.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );

    for name in ["rf_f32_b64", "rf_i16_b64"] {
        let metas = load_manifest(&dir)?;
        let meta = metas.iter().find(|m| m.name == name).unwrap();
        let forest = io::load(&dir.join(&meta.forest))?;
        println!(
            "== artifact {name}: M={} K={} L={} d={} C={} batch={} dtype={:?} ==",
            meta.n_trees, meta.k, meta.leaf_words, meta.d, meta.c, meta.batch, meta.dtype
        );

        let tensor = TensorEngine::from_artifact(&dir, name, &forest)?;
        let qs = build(EngineKind::Qs, Precision::F32, &forest, None)?;
        let rs = build(EngineKind::Rs, Precision::F32, &forest, None)?;

        // Numerics: tensor path vs native QS on random inputs.
        let mut rng = Pcg32::seeded(0x0FF);
        let n = meta.batch * 4;
        let x: Vec<f32> = (0..n * forest.n_features).map(|_| rng.f32()).collect();
        let t_scores = tensor.predict(&x);
        let q_scores = qs.predict(&x);
        let max_diff = t_scores
            .iter()
            .zip(&q_scores)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        println!("  max |XLA - QS| over {n} instances: {max_diff:.2e}");
        if meta.scale <= 1.0 {
            anyhow::ensure!(max_diff < 1e-3, "tensor path diverged from native");
        }

        // Throughput comparison.
        for (label, engine) in
            [("XLA", &tensor as &dyn Engine), ("QS", qs.as_ref()), ("RS", rs.as_ref())]
        {
            let t = time_per_instance(engine, &x, 3);
            println!("  {label:<4} {t:>9.2} µs/instance");
        }
        println!();
    }
    println!("tensor offload OK: AOT path and native engines agree.");
    Ok(())
}
