//! Device advisor: which engine should you deploy for *this* forest on
//! *that* device?
//!
//! The paper's conclusion is that the best implementation depends on the
//! (forest × device) combination. This example makes the advice concrete:
//! it trains forests of several shapes, scores all ten engine variants with
//! the per-device cost models (Cortex-A53 / Exynos-5422 big / A7 LITTLE),
//! and prints a recommendation matrix.
//!
//! ```sh
//! cargo run --release --example device_advisor
//! ```

use arbors::coordinator::select_engine;
use arbors::data::DatasetId;
use arbors::device::DeviceProfile;
use arbors::forest::builder::{train_random_forest, RfParams, TreeParams};

fn main() -> anyhow::Result<()> {
    let devices = [
        DeviceProfile::cortex_a53(),
        DeviceProfile::exynos_5422_big(),
        DeviceProfile::exynos_5422_little(),
    ];
    let shapes = [(64usize, 32usize), (64, 64), (256, 64)];
    let datasets = [DatasetId::Magic, DatasetId::Adult, DatasetId::Mnist];

    println!(
        "{:<9} {:<10} {:<28} {:<8} {:>14}",
        "dataset", "forest", "device", "best", "est µs/inst"
    );
    println!("{}", "-".repeat(75));

    for id in datasets {
        let ds = id.generate(2500.min(id.default_n()), 7);
        let (train, test) = ds.split(0.2, 3);
        for (trees, leaves) in shapes {
            let f = train_random_forest(
                &train.x,
                &train.labels,
                train.d,
                train.n_classes,
                RfParams {
                    n_trees: trees,
                    tree: TreeParams { max_leaves: leaves, min_samples_leaf: 2, mtry: 0 },
                    ..Default::default()
                },
            );
            for dev in &devices {
                let sel =
                    select_engine(&f, &test.x[..test.d * 128], Some(dev), 2)?;
                let best = sel.best();
                println!(
                    "{:<9} {:<10} {:<28} {:<8} {:>14.2}",
                    id.name(),
                    format!("{trees}x{leaves}"),
                    dev.name,
                    best.name,
                    best.device_us_per_instance.unwrap()
                );
            }
        }
    }
    println!(
        "\n(estimates from the per-microarchitecture cost model; see DESIGN.md\n\
         §Substitutions — the finding under reproduction is that the winner\n\
         changes with the device and the forest, Figure 2 / §6.3)"
    );
    Ok(())
}
