//! Perf probe: stable median-of-15 timing of the hot engines (used only by
//! the §Perf optimization loop; see EXPERIMENTS.md).
use arbors::bench::harness::{build_engine_arc, cached_rf, eval_batch, time_per_instance, Scale};
use arbors::data::DatasetId;
use arbors::engine::{EngineKind, Precision};

fn main() {
    let scale = Scale::from_env();
    let ds = DatasetId::Magic.generate(DatasetId::Magic.default_n(), 0xD5 ^ 64);
    let (train, _) = ds.split(0.2, 7);
    let f = cached_rf(&train, scale.cls_trees, 64);
    let x = eval_batch(&ds, 256);
    for (label, kind, prec) in [
        ("QS", EngineKind::Qs, Precision::F32),
        ("VQS", EngineKind::Vqs, Precision::F32),
        ("RS", EngineKind::Rs, Precision::F32),
        ("qRS", EngineKind::Rs, Precision::I16),
        ("NA", EngineKind::Naive, Precision::F32),
    ] {
        let e = build_engine_arc(kind, prec, &f).unwrap();
        let t = time_per_instance(e.as_ref(), &x, 15);
        println!("{label:<5} {t:.3} us/inst");
    }
}
