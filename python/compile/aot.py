"""AOT pipeline: lower the L2 forest-evaluation graph to HLO text artifacts.

Python runs ONCE at build time (`make artifacts`); the Rust coordinator loads
the HLO via PJRT and Python never appears on the request path.

Interchange format is **HLO text**, not a serialized HloModuleProto: jax
≥ 0.5 emits protos with 64-bit instruction ids which the published `xla`
crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example/README.md
and gen_hlo.py there).

Outputs in `artifacts/`:
  <name>.hlo.txt      — the lowered module (entry: x, thr, fid, mask_lo,
                        mask_hi, leaves → (scores,))
  <name>.forest.json  — the fixture forest in `arbors-forest-v1` format
  manifest.json       — shapes/dtypes for every artifact (read by rust)

Usage:
  python -m compile.aot --out-dir ../artifacts                 # defaults
  python -m compile.aot --forest f.json --batch 64 --name my   # custom
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .forest import Forest, encode_qs, random_forest, save_forest
from .kernels.quickscorer import vmem_bytes
from .model import forest_eval, quantize_tensors


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_forest(
    forest: Forest,
    batch: int,
    *,
    dtype: str = "f32",
    scale: float = 32768.0,
    block_b: int | None = None,
    block_m: int | None = None,
):
    """Lower one forest shape; returns (hlo_text, meta dict)."""
    t = encode_qs(forest)
    m, k = t.thr.shape
    _, l, c = t.leaves.shape
    d = forest.n_features

    if dtype == "f32":
        x_spec = jax.ShapeDtypeStruct((batch, d), jnp.float32)
        thr_spec = jax.ShapeDtypeStruct((m, k), jnp.float32)
        leaves_spec = jax.ShapeDtypeStruct((m, l, c), jnp.float32)
    elif dtype == "i16":
        x_spec = jax.ShapeDtypeStruct((batch, d), jnp.int16)
        thr_spec = jax.ShapeDtypeStruct((m, k), jnp.int16)
        leaves_spec = jax.ShapeDtypeStruct((m, l, c), jnp.int16)
    else:
        raise ValueError(dtype)

    fid_spec = jax.ShapeDtypeStruct((m, k), jnp.int32)
    mask_spec = jax.ShapeDtypeStruct((m, k), jnp.uint32)

    def fn(x, thr, fid, mlo, mhi, leaves):
        return forest_eval(x, thr, fid, mlo, mhi, leaves, block_b=block_b, block_m=block_m)

    lowered = jax.jit(fn).lower(
        x_spec, thr_spec, fid_spec, mask_spec, mask_spec, leaves_spec
    )
    hlo = to_hlo_text(lowered)
    meta = {
        "batch": batch,
        "n_trees": m,
        "k": k,
        "leaf_words": l,
        "d": d,
        "c": c,
        "dtype": dtype,
        "scale": scale if dtype == "i16" else 1.0,
        "block_b": block_b or batch,
        "block_m": block_m or m,
        "vmem_bytes": vmem_bytes(
            block_b or batch, block_m or m, d, k, l, c, 4 if dtype == "f32" else 2
        ),
    }
    return hlo, meta


def build_default_artifacts(out_dir: str) -> dict:
    """The fixture artifact set: a float and an int16 model of the same
    random forest, plus a larger L=64 float model."""
    os.makedirs(out_dir, exist_ok=True)
    manifest: dict = {"format": "arbors-artifacts-v1", "models": []}

    configs = [
        # (name, trees, features, classes, max_leaves, batch, dtype, Bb, Mb)
        ("rf_f32_b64", 128, 32, 2, 32, 64, "f32", 32, 32),
        ("rf_i16_b64", 128, 32, 2, 32, 64, "i16", 32, 32),
        ("rf_f32_l64_b32", 64, 16, 3, 64, 32, "f32", 16, 16),
    ]
    for name, n_trees, d, c, max_leaves, batch, dtype, bb, mb in configs:
        forest = random_forest(
            seed=hash(name) % (2**31), n_trees=n_trees, n_features=d,
            n_classes=c, max_leaves=max_leaves,
        )
        hlo, meta = lower_forest(forest, batch, dtype=dtype, block_b=bb, block_m=mb)
        hlo_path = f"{name}.hlo.txt"
        forest_path = f"{name}.forest.json"
        with open(os.path.join(out_dir, hlo_path), "w") as f:
            f.write(hlo)
        save_forest(forest, os.path.join(out_dir, forest_path))
        meta.update({"name": name, "hlo": hlo_path, "forest": forest_path})
        manifest["models"].append(meta)
        print(f"wrote {hlo_path}: {meta}")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def build_custom(out_dir: str, forest_path: str, name: str, batch: int,
                 dtype: str, scale: float) -> None:
    from .forest import load_forest

    os.makedirs(out_dir, exist_ok=True)
    forest = load_forest(forest_path)
    hlo, meta = lower_forest(forest, batch, dtype=dtype, scale=scale)
    hlo_path = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, hlo_path), "w") as f:
        f.write(hlo)
    fj = f"{name}.forest.json"
    save_forest(forest, os.path.join(out_dir, fj))
    meta.update({"name": name, "hlo": hlo_path, "forest": fj})
    manifest_path = os.path.join(out_dir, "manifest.json")
    manifest = {"format": "arbors-artifacts-v1", "models": []}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)
    manifest["models"] = [m for m in manifest["models"] if m["name"] != name]
    manifest["models"].append(meta)
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {hlo_path}: {meta}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="legacy single-file mode (unused)")
    ap.add_argument("--forest", default=None, help="compile a custom forest JSON")
    ap.add_argument("--name", default="custom")
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--dtype", choices=["f32", "i16"], default="f32")
    ap.add_argument("--scale", type=float, default=32768.0)
    args = ap.parse_args()

    if args.forest:
        build_custom(args.out_dir, args.forest, args.name, args.batch, args.dtype, args.scale)
    else:
        build_default_artifacts(args.out_dir)


if __name__ == "__main__":
    main()
