"""Forest structures on the Python side of the build pipeline.

Mirrors the Rust `arbors-forest-v1` JSON format (rust/src/forest/io.rs):
trees with flat node arrays, children encoded as ``>= 0`` (inner-node index)
or ``-(leaf+1)`` (leaf id), leaves numbered left-to-right, leaf values
row-major ``[n_leaves, n_classes]``.

Provides:

* loading/saving the shared JSON format,
* a seeded random-forest generator (for artifact fixtures and kernel tests),
* the QuickScorer tensor encoding consumed by the L1 Pallas kernel:
  thresholds/feature-ids ``[M, K]``, bitvector masks as two uint32 planes
  (bit *i* of the 64-bit concatenation = leaf *i*; zeros over a false node's
  left-subtree leaves), and the padded leaf table ``[M, L, C]``.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field

import numpy as np


@dataclass
class Tree:
    feature: np.ndarray  # [n_nodes] int32
    threshold: np.ndarray  # [n_nodes] float32
    left: np.ndarray  # [n_nodes] int32 (child encoding)
    right: np.ndarray  # [n_nodes] int32
    leaf_values: np.ndarray  # [n_leaves, n_classes] float32

    @property
    def n_leaves(self) -> int:
        return self.leaf_values.shape[0]

    @property
    def n_nodes(self) -> int:
        return len(self.feature)

    def exit_leaf(self, x: np.ndarray) -> int:
        """Reference walk for one instance (split: x[k] <= t goes left)."""
        if self.n_nodes == 0:
            return 0
        cur = 0
        while True:
            nxt = (
                self.left[cur]
                if x[self.feature[cur]] <= self.threshold[cur]
                else self.right[cur]
            )
            if nxt < 0:
                return -int(nxt) - 1
            cur = int(nxt)

    def left_leaf_ranges(self) -> list[tuple[int, int]]:
        """Per inner node: the [begin, end) leaf range of its left subtree."""
        out = [(0, 0)] * self.n_nodes
        if self.n_nodes == 0:
            return out

        def span(child: int) -> tuple[int, int]:
            if child < 0:
                leaf = -child - 1
                return leaf, leaf + 1
            lb, le = span(int(self.left[child]))
            rb, re = span(int(self.right[child]))
            assert le == rb, "leaves must be numbered left-to-right"
            out[child] = (lb, le)
            return lb, re

        span(0)
        return out


@dataclass
class Forest:
    trees: list[Tree]
    n_features: int
    n_classes: int
    task: str = "classification"
    base_score: np.ndarray = field(default_factory=lambda: np.zeros(0, np.float32))

    @property
    def n_trees(self) -> int:
        return len(self.trees)

    @property
    def max_leaves(self) -> int:
        return max(t.n_leaves for t in self.trees)


def load_forest(path: str) -> Forest:
    with open(path) as f:
        j = json.load(f)
    assert j["format"] == "arbors-forest-v1", j.get("format")
    trees = [
        Tree(
            feature=np.asarray(t["feature"], np.int32),
            threshold=np.asarray(t["threshold"], np.float32),
            left=np.asarray(t["left"], np.int32),
            right=np.asarray(t["right"], np.int32),
            leaf_values=np.asarray(t["leaf_values"], np.float32).reshape(
                t["n_leaves"], j["n_classes"]
            ),
        )
        for t in j["trees"]
    ]
    return Forest(
        trees=trees,
        n_features=j["n_features"],
        n_classes=j["n_classes"],
        task=j["task"],
        base_score=np.asarray(j["base_score"], np.float32),
    )


def save_forest(forest: Forest, path: str) -> None:
    j = {
        "format": "arbors-forest-v1",
        "task": forest.task,
        "n_features": forest.n_features,
        "n_classes": forest.n_classes,
        "base_score": [float(v) for v in forest.base_score],
        "trees": [
            {
                "feature": t.feature.tolist(),
                "threshold": [float(v) for v in t.threshold],
                "left": t.left.tolist(),
                "right": t.right.tolist(),
                "leaf_values": [float(v) for v in t.leaf_values.reshape(-1)],
                "n_leaves": int(t.n_leaves),
            }
            for t in forest.trees
        ],
    }
    with open(path, "w") as f:
        json.dump(j, f)


def random_tree(rng: np.random.Generator, n_features: int, n_classes: int,
                n_leaves: int) -> Tree:
    """Grow a random tree with exactly `n_leaves` leaves by repeatedly
    splitting a random leaf; leaves are renumbered left-to-right at the end.
    """
    # Structure as nested lists: node = [feature, thr, left, right];
    # leaf = None placeholder replaced by ids later.
    tree: list = ["leaf"]

    def count_leaves(node) -> int:
        if node[0] == "leaf":
            return 1
        return count_leaves(node[2]) + count_leaves(node[3])

    def split_random_leaf(node) -> bool:
        if node[0] == "leaf":
            node[:] = [
                int(rng.integers(n_features)),
                float(rng.uniform(0.05, 0.95)),
                ["leaf"],
                ["leaf"],
            ]
            return True
        branch = node[2] if rng.random() < 0.5 else node[3]
        return split_random_leaf(branch)

    while count_leaves(tree) < n_leaves:
        split_random_leaf(tree)

    feature, threshold, left, right = [], [], [], []
    leaf_values: list[np.ndarray] = []

    def emit(node) -> int:
        """Returns the child encoding of this subtree."""
        if node[0] == "leaf":
            leaf_values.append(rng.normal(size=n_classes).astype(np.float32) * 0.1)
            return -(len(leaf_values) - 1) - 1
        idx = len(feature)
        feature.append(node[0])
        threshold.append(node[1])
        left.append(0)
        right.append(0)
        left[idx] = emit(node[2])
        right[idx] = emit(node[3])
        return idx

    emit(tree)
    return Tree(
        feature=np.asarray(feature, np.int32),
        threshold=np.asarray(threshold, np.float32),
        left=np.asarray(left, np.int32),
        right=np.asarray(right, np.int32),
        leaf_values=np.stack(leaf_values),
    )


def random_forest(seed: int, n_trees: int, n_features: int, n_classes: int,
                  max_leaves: int) -> Forest:
    """Seeded random forest for fixtures: tree i has 2..max_leaves leaves."""
    rng = np.random.default_rng(seed)
    trees = [
        random_tree(rng, n_features, n_classes,
                    int(rng.integers(2, max_leaves + 1)))
        for _ in range(n_trees)
    ]
    return Forest(
        trees=trees,
        n_features=n_features,
        n_classes=n_classes,
        base_score=np.zeros(n_classes, np.float32),
    )


@dataclass
class QsTensors:
    """QuickScorer tensor encoding with static shapes (see module docs)."""

    thr: np.ndarray  # [M, K] float32 (+inf padding)
    fid: np.ndarray  # [M, K] int32
    mask_lo: np.ndarray  # [M, K] uint32 (bits 0..31 of the leaf bitvector)
    mask_hi: np.ndarray  # [M, K] uint32 (bits 32..63)
    leaves: np.ndarray  # [M, L, C] float32 (padded rows zero)
    leaf_words: int  # 32 or 64

    @property
    def shapes(self) -> dict:
        m, k = self.thr.shape
        _, l, c = self.leaves.shape
        return {"n_trees": m, "k": k, "leaf_words": l, "c": c}


def encode_qs(forest: Forest) -> QsTensors:
    """Encode a forest into the dense QuickScorer tensors.

    Unlike the scalar algorithm, the tensorized kernel AND-reduces over *all*
    nodes (no early exit), so node order within a tree is irrelevant; trees
    with fewer nodes are padded with `thr = +inf` (never a false node).
    """
    leaf_words = 32 if forest.max_leaves <= 32 else 64
    assert forest.max_leaves <= 64, "QuickScorer tensors support <= 64 leaves"
    m = forest.n_trees
    k = max(max(t.n_nodes for t in forest.trees), 1)
    c = forest.n_classes

    thr = np.full((m, k), np.inf, np.float32)
    fid = np.zeros((m, k), np.int32)
    mask_lo = np.full((m, k), 0xFFFFFFFF, np.uint32)
    mask_hi = np.full((m, k), 0xFFFFFFFF, np.uint32)
    leaves = np.zeros((m, leaf_words, c), np.float32)

    for ti, t in enumerate(forest.trees):
        ranges = t.left_leaf_ranges()
        for ni in range(t.n_nodes):
            b, e = ranges[ni]
            width = e - b
            ones = (1 << width) - 1
            mask64 = ~(ones << b) & 0xFFFFFFFFFFFFFFFF
            thr[ti, ni] = t.threshold[ni]
            fid[ti, ni] = t.feature[ni]
            mask_lo[ti, ni] = mask64 & 0xFFFFFFFF
            mask_hi[ti, ni] = (mask64 >> 32) & 0xFFFFFFFF
        leaves[ti, : t.n_leaves] = t.leaf_values

    return QsTensors(thr, fid, mask_lo, mask_hi, leaves, leaf_words)
