"""L1 alternative: tree traversal as matrix multiplication (MXU variant).

The bitvector kernel (`quickscorer.py`) is a VPU workload — compares and
masks, no matmul. TPUs, however, earn their FLOPs on the MXU systolic
array, and the paper's related work (Nakandala et al. 2020, "Hummingbird")
shows tree traversal can be recast as dense tensor algebra. This module
implements that GEMM formulation as a second Pallas kernel so the repo can
quantify the trade-off the paper alludes to: *"mapping DT traversal to
tensor operations usually leads to an increase in computation, but this
increase is justified due to the availability of more efficient tensor
hardware."*

Encoding (per tree, padded to the forest maxima):

* ``A``  [d, K]      one-hot: A[f, n] = 1 if node n tests feature f
* ``t``  [K]         node thresholds
* ``B``  [K, L]      path matrix: B[n, l] = +1 if leaf l is in n's left
                     subtree, -1 if in its right subtree, else 0
* ``cnt`` [L]        number of internal nodes on the path to leaf l

Evaluation for an instance x:

1. ``s = step(tᵀ - xᵀA)``  — s[n] = 1 if x goes left at node n (x ≤ t)
2. ``r = (2s - 1) B``      — r[l] counts path agreements minus disagreements
3. exit leaf = argmax over l of (r[l] == cnt[l])  (exactly one leaf matches
   all of its path decisions)
4. score = leaf_values[exit leaf]

Steps 1 and 2 are batched matmuls → MXU work. The kernel tiles over
(batch × trees) like the bitvector kernel. On real TPU the matmuls would run
in bf16 with f32 accumulation; interpret mode executes them as f32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..forest import Forest


def encode_gemm(forest: Forest):
    """Encode a forest into the GEMM tensors.

    Returns dict of numpy arrays: A [M, d, K], thr [M, K], B [M, K, L],
    cnt [M, L], leaves [M, L, C].
    """
    m = forest.n_trees
    d = forest.n_features
    k = max(max(t.n_nodes for t in forest.trees), 1)
    l = forest.max_leaves
    c = forest.n_classes

    a = np.zeros((m, d, k), np.float32)
    thr = np.full((m, k), np.float32(np.finfo(np.float32).max / 2), np.float32)
    b = np.zeros((m, k, l), np.float32)
    cnt = np.zeros((m, l), np.float32)
    leaves = np.zeros((m, l, c), np.float32)

    for ti, tree in enumerate(forest.trees):
        leaves[ti, : tree.n_leaves] = tree.leaf_values
        # Walk every root-to-leaf path collecting (node, direction).
        def walk(child: int, path):
            if child < 0:
                leaf = -child - 1
                cnt[ti, leaf] = len(path)
                for node, went_left in path:
                    b[ti, node, leaf] = 1.0 if went_left else -1.0
                return
            walk(int(tree.left[child]), path + [(child, True)])
            walk(int(tree.right[child]), path + [(child, False)])

        if tree.n_nodes:
            walk(0, [])
            for n in range(tree.n_nodes):
                a[ti, tree.feature[n], n] = 1.0
                thr[ti, n] = tree.threshold[n]
        else:
            cnt[ti, 0] = 0.0
    return {"a": a, "thr": thr, "b": b, "cnt": cnt, "leaves": leaves}


def _kernel(x_ref, a_ref, thr_ref, b_ref, cnt_ref, leaves_ref, o_ref):
    m_idx = pl.program_id(1)
    x = x_ref[...]  # [Bb, d]
    a = a_ref[...]  # [Mb, d, K]
    thr = thr_ref[...]  # [Mb, K]
    b = b_ref[...]  # [Mb, K, L]
    cnt = cnt_ref[...]  # [Mb, L]
    leaves = leaves_ref[...]  # [Mb, L, C]

    # Step 1 — feature selection matmul (MXU): xa[m, i, n] = x[i] · A[m].
    xa = jnp.einsum("id,mdk->mik", x, a)  # [Mb, Bb, K]
    s = (xa <= thr[:, None, :]).astype(jnp.float32)  # left decisions

    # Step 2 — path-agreement matmul (MXU).
    r = jnp.einsum("mik,mkl->mil", 2.0 * s - 1.0, b)  # [Mb, Bb, L]

    # Step 3 — the exit leaf matches all its path decisions.
    hit = (r == cnt[:, None, :]).astype(jnp.float32)  # [Mb, Bb, L]

    # Step 4 — gather = one more matmul: scores[m, i, c] = hit · leaves[m].
    partial = jnp.einsum("mil,mlc->ic", hit, leaves)  # [Bb, C]

    @pl.when(m_idx == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(m_idx != 0)
    def _acc():
        o_ref[...] += partial


def gemm_forest_eval(x, a, thr, b, cnt, leaves, *, block_b=None, block_m=None,
                     interpret: bool = True):
    """Evaluate the GEMM-encoded forest; returns [B, C] f32 scores."""
    bsz, d = x.shape
    m, _, k = a.shape
    _, l, c = leaves.shape
    block_b = block_b or bsz
    block_m = block_m or m
    assert bsz % block_b == 0 and m % block_m == 0

    grid = (bsz // block_b, m // block_m)
    return pl.pallas_call(
        functools.partial(_kernel),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i, mm: (i, 0)),
            pl.BlockSpec((block_m, d, k), lambda i, mm: (mm, 0, 0)),
            pl.BlockSpec((block_m, k), lambda i, mm: (mm, 0)),
            pl.BlockSpec((block_m, k, l), lambda i, mm: (mm, 0, 0)),
            pl.BlockSpec((block_m, l), lambda i, mm: (mm, 0)),
            pl.BlockSpec((block_m, l, c), lambda i, mm: (mm, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, c), lambda i, mm: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, c), jnp.float32),
        interpret=interpret,
    )(x, a, thr, b, cnt, leaves)


def gemm_flops(batch: int, m: int, d: int, k: int, l: int, c: int) -> int:
    """MACs per batch for the three matmuls — the 'increase in computation'
    the tensor formulation pays (compare against ~nodes-visited for
    QuickScorer)."""
    return batch * m * (d * k + k * l + l * c)
