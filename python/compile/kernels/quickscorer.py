"""L1: the QuickScorer traversal as a Pallas kernel.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper vectorizes
QuickScorer across v instances in 128-bit NEON registers. On TPU the same
insight — *replace pointer-chasing descent with feature compares + bitvector
AND-masking over dense node arrays* — maps to the VPU: the batch dimension
plays the role of the NEON lanes, one broadcast compare tests a whole
(batch-tile × node-tile) block, masks combine with a bitwise AND reduction,
and the exit leaf falls out of a count-trailing-zeros (`lax.clz`) instead of
NEON's `vrbitq`+`vclzq` trick.

Bitvector encoding: leaf `i` of a tree is bit `i` of a 64-bit word stored as
two uint32 planes (`mask_lo` = bits 0..31, `mask_hi` = bits 32..63). A false
node (x[k] > t) contributes zeros over its left subtree's leaf range; the
exit leaf is the lowest set bit of the AND of all contributions — computed
per (instance, tree) without any branching.

The kernel runs under ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so interpret mode is both the correctness path and what
``aot.py`` lowers into the artifacts (see /opt/xla-example/README.md). The
BlockSpec structure (HBM→VMEM tiles over batch × trees) is still the real
TPU schedule; EXPERIMENTS.md §Perf derives the VMEM footprint from it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

_FULL = 0xFFFFFFFF


def _tz32(w):
    """Index of the lowest set bit of a uint32; 32 when w == 0.

    ctz(w) = 31 - clz(w & -w); the NEON equivalent is Alg. 4's
    vclzq(vrbitq(b)) byte trick.
    """
    isolated = jnp.bitwise_and(w, jnp.bitwise_not(w) + jnp.uint32(1))
    return jnp.where(
        w == jnp.uint32(0),
        jnp.int32(32),
        jnp.int32(31) - lax.clz(isolated).astype(jnp.int32),
    )


def _kernel(x_ref, thr_ref, fid_ref, mlo_ref, mhi_ref, leaves_ref, o_ref, *, acc_dtype):
    """One (batch-tile, tree-tile) block of the traversal."""
    m_idx = pl.program_id(1)
    x = x_ref[...]  # [Bb, d]
    thr = thr_ref[...]  # [Mb, K]
    fid = fid_ref[...]  # [Mb, K]  int32
    mlo = mlo_ref[...]  # [Mb, K]  uint32
    mhi = mhi_ref[...]
    leaves = leaves_ref[...]  # [Mb, L, C]

    bb = x.shape[0]
    mb, k = thr.shape

    # Gather the tested feature of every node for every instance:
    # xk[b, m, n] = x[b, fid[m, n]].
    xk = jnp.take(x, fid.reshape(-1), axis=1).reshape(bb, mb, k)

    # Mask computation: false nodes contribute their bitvector, true nodes
    # contribute all-ones (identity of AND). Padded nodes have thr=+inf
    # (float) / 32767 (int16) and are never false.
    cond = xk > thr[None, :, :]
    full = jnp.uint32(_FULL)
    lo = jnp.where(cond, mlo[None, :, :], full)
    hi = jnp.where(cond, mhi[None, :, :], full)
    lo = lax.reduce(lo, full, lax.bitwise_and, dimensions=[2])  # [Bb, Mb]
    hi = lax.reduce(hi, full, lax.bitwise_and, dimensions=[2])

    # Exit leaf: lowest set bit across the 64-bit (hi:lo) concatenation.
    j = jnp.where(lo != jnp.uint32(0), _tz32(lo), jnp.int32(32) + _tz32(hi))

    # Score: gather each (instance, tree)'s leaf row and sum over the tile's
    # trees.
    vals = leaves[jnp.arange(mb)[None, :], j]  # [Bb, Mb, C]
    partial = jnp.sum(vals.astype(acc_dtype), axis=1)  # [Bb, C]

    @pl.when(m_idx == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(m_idx != 0)
    def _acc():
        o_ref[...] += partial


def quickscorer(
    x,
    thr,
    fid,
    mask_lo,
    mask_hi,
    leaves,
    *,
    block_b: int | None = None,
    block_m: int | None = None,
    interpret: bool = True,
):
    """Evaluate a QuickScorer-encoded forest on a batch.

    Args:
        x: [B, d] features — float32 for the float model, int16 for the
           fixed-point model (pre-quantized with the model's scale).
        thr: [M, K] node thresholds (same dtype as ``x``; padding +inf /
           int16 max).
        fid: [M, K] int32 feature ids.
        mask_lo / mask_hi: [M, K] uint32 bitvector planes.
        leaves: [M, L, C] leaf values — float32 or int16.
        block_b / block_m: VMEM tile sizes (must divide B and M); default
           whole array.
        interpret: run the Pallas interpreter (required on CPU PJRT).

    Returns:
        [B, C] scores — float32 for float models, int32 (undescaled) for
        int16 models.
    """
    b, _ = x.shape
    m, k = thr.shape
    _, l, c = leaves.shape
    block_b = block_b or b
    block_m = block_m or m
    assert b % block_b == 0, (b, block_b)
    assert m % block_m == 0, (m, block_m)
    assert x.dtype == thr.dtype, (x.dtype, thr.dtype)

    acc_dtype = jnp.float32 if leaves.dtype == jnp.float32 else jnp.int32
    grid = (b // block_b, m // block_m)
    d = x.shape[1]

    return pl.pallas_call(
        functools.partial(_kernel, acc_dtype=acc_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i, mm: (i, 0)),
            pl.BlockSpec((block_m, k), lambda i, mm: (mm, 0)),
            pl.BlockSpec((block_m, k), lambda i, mm: (mm, 0)),
            pl.BlockSpec((block_m, k), lambda i, mm: (mm, 0)),
            pl.BlockSpec((block_m, k), lambda i, mm: (mm, 0)),
            pl.BlockSpec((block_m, l, c), lambda i, mm: (mm, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, c), lambda i, mm: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, c), acc_dtype),
        interpret=interpret,
    )(x, thr, fid, mask_lo, mask_hi, leaves)


def vmem_bytes(block_b: int, block_m: int, d: int, k: int, l: int, c: int,
               dtype_bytes: int = 4) -> int:
    """Estimated VMEM footprint of one kernel invocation (for the §Perf
    tables): input tiles + output tile + the [Bb, Mb, K] gather intermediate
    that dominates."""
    x_tile = block_b * d * dtype_bytes
    node_tiles = block_m * k * (dtype_bytes + 4 + 4 + 4)
    leaf_tile = block_m * l * c * dtype_bytes
    out_tile = block_b * c * 4
    gather = block_b * block_m * k * dtype_bytes
    masks = 2 * block_b * block_m * 4
    return x_tile + node_tiles + leaf_tile + out_tile + gather + masks
