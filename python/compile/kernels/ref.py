"""Pure-numpy correctness oracle for the QuickScorer Pallas kernel.

Deliberately *independent* of the kernel's tensor encoding: it walks each
tree node-by-node from the structural (children-array) representation, so a
bug in `encode_qs` or in the kernel's bitvector math cannot cancel out.
"""

from __future__ import annotations

import numpy as np

from ..forest import Forest


def predict_forest(forest: Forest, x: np.ndarray) -> np.ndarray:
    """Reference scores: walk every tree for every instance.

    Args:
        forest: structural forest.
        x: [B, d] float32.

    Returns:
        [B, C] float32 scores (sum of leaf vectors + base score).
    """
    b = x.shape[0]
    base = (
        forest.base_score.astype(np.float32)
        if forest.base_score.size
        else np.zeros(forest.n_classes, np.float32)
    )
    out = np.tile(base, (b, 1))
    for t in forest.trees:
        for i in range(b):
            leaf = t.exit_leaf(x[i])
            out[i] += t.leaf_values[leaf]
    return out


def predict_forest_quant(forest: Forest, x: np.ndarray, scale: float) -> np.ndarray:
    """Reference for the int16 fixed-point path (paper eq. 3): thresholds,
    leaves and features quantized with ``q(v) = floor(scale * v)`` saturated
    to i16; scores accumulate in i32 and descale at the end."""

    def q(v: np.ndarray) -> np.ndarray:
        return np.clip(np.floor(scale * np.asarray(v, np.float64)), -32768, 32767).astype(
            np.int16
        )

    b = x.shape[0]
    qx = q(x)
    acc = np.zeros((b, forest.n_classes), np.int32)
    for t in forest.trees:
        qthr = q(t.threshold)
        qleaf = q(t.leaf_values)
        for i in range(b):
            if t.n_nodes == 0:
                leaf = 0
            else:
                cur = 0
                while True:
                    nxt = (
                        t.left[cur]
                        if qx[i, t.feature[cur]] <= qthr[cur]
                        else t.right[cur]
                    )
                    if nxt < 0:
                        leaf = -int(nxt) - 1
                        break
                    cur = int(nxt)
            acc[i] += qleaf[leaf].astype(np.int32)
    base = np.floor(scale * forest.base_score).astype(np.int32)
    return (acc + base).astype(np.float32) / np.float32(scale)
