"""L2: the forest-evaluation compute graph.

Two equivalent paths:

* :func:`forest_eval` — calls the L1 Pallas kernel (this is what `aot.py`
  lowers into the serving artifacts);
* :func:`forest_eval_jnp` — the same math in plain jnp (XLA-fused tensor
  ops), used as the L2 cross-check and as the "tensor-compiler baseline" in
  the ablation bench (cf. Nakandala et al. 2020 in the paper's related work).

Both are pure functions of `(x, thr, fid, mask_lo, mask_hi, leaves)` so the
lowered HLO takes the forest as runtime inputs: one artifact per *shape*
(B, M, K, L, C), reusable across forests of that shape.

The int16 fixed-point model (paper §5) takes pre-quantized i16 features and
returns undescaled i32 scores — the request path stays integer-only, the
Rust side descales.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from .kernels.quickscorer import quickscorer

_FULL = 0xFFFFFFFF


def forest_eval(x, thr, fid, mask_lo, mask_hi, leaves, *, block_b=None, block_m=None):
    """Pallas-kernel forest evaluation; returns a 1-tuple for AOT lowering
    (the HLO bridge unwraps `to_tuple1` on the Rust side)."""
    scores = quickscorer(
        x, thr, fid, mask_lo, mask_hi, leaves, block_b=block_b, block_m=block_m
    )
    return (scores,)


def forest_eval_jnp(x, thr, fid, mask_lo, mask_hi, leaves):
    """Plain-jnp reference of the same tensorized traversal."""
    b = x.shape[0]
    m, k = thr.shape
    xk = jnp.take(x, fid.reshape(-1), axis=1).reshape(b, m, k)
    cond = xk > thr[None, :, :]
    full = jnp.uint32(_FULL)
    lo = lax.reduce(jnp.where(cond, mask_lo[None], full), full, lax.bitwise_and, dimensions=[2])
    hi = lax.reduce(jnp.where(cond, mask_hi[None], full), full, lax.bitwise_and, dimensions=[2])

    def tz32(w):
        isolated = jnp.bitwise_and(w, jnp.bitwise_not(w) + jnp.uint32(1))
        return jnp.where(
            w == jnp.uint32(0),
            jnp.int32(32),
            jnp.int32(31) - lax.clz(isolated).astype(jnp.int32),
        )

    j = jnp.where(lo != jnp.uint32(0), tz32(lo), jnp.int32(32) + tz32(hi))
    vals = leaves[jnp.arange(m)[None, :], j]  # [B, M, C]
    acc_dtype = jnp.float32 if leaves.dtype == jnp.float32 else jnp.int32
    return (jnp.sum(vals.astype(acc_dtype), axis=1),)


def quantize_tensors(thr, leaves, scale: float):
    """Fixed-point model tensors (paper eq. 3): q(v) = floor(scale * v),
    saturated to int16. Padded +inf thresholds map to int16 max, preserving
    the 'never false' property."""
    import numpy as np

    def q(v):
        return np.clip(np.floor(scale * np.asarray(v, np.float64)), -32768, 32767).astype(
            np.int16
        )

    return q(thr), q(leaves)


def quantize_features(x, scale: float):
    """Quantize a feature batch for the int16 model."""
    import numpy as np

    return np.clip(np.floor(scale * np.asarray(x, np.float64)), -32768, 32767).astype(
        np.int16
    )
