"""L1 correctness: the Pallas QuickScorer kernel vs the numpy tree-walk
oracle, with hypothesis sweeping forest shapes, batch sizes, tilings and
dtypes. This is the CORE correctness signal of the compile path."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.forest import encode_qs, random_forest
from compile.kernels.ref import predict_forest, predict_forest_quant
from compile.model import (
    forest_eval,
    forest_eval_jnp,
    quantize_features,
    quantize_tensors,
)


def _make(seed, n_trees, d, c, max_leaves):
    f = random_forest(seed=seed, n_trees=n_trees, n_features=d, n_classes=c,
                      max_leaves=max_leaves)
    t = encode_qs(f)
    return f, t


def _x(seed, b, d):
    rng = np.random.default_rng(seed)
    return rng.uniform(0, 1, size=(b, d)).astype(np.float32)


def test_kernel_matches_oracle_basic():
    f, t = _make(1, 12, 8, 2, 32)
    x = _x(2, 32, 8)
    ref = predict_forest(f, x)
    got = np.asarray(forest_eval(x, t.thr, t.fid, t.mask_lo, t.mask_hi, t.leaves)[0])
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


def test_kernel_l64_two_planes():
    f, t = _make(3, 6, 5, 2, 64)
    assert f.max_leaves > 32, "fixture must exercise the hi mask plane"
    assert t.leaf_words == 64
    x = _x(4, 16, 5)
    ref = predict_forest(f, x)
    got = np.asarray(forest_eval(x, t.thr, t.fid, t.mask_lo, t.mask_hi, t.leaves)[0])
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    n_trees=st.integers(1, 24),
    d=st.integers(1, 20),
    c=st.integers(1, 5),
    max_leaves=st.sampled_from([2, 4, 8, 16, 32, 48, 64]),
    b=st.integers(1, 40),
)
def test_kernel_matches_oracle_sweep(seed, n_trees, d, c, max_leaves, b):
    f, t = _make(seed, n_trees, d, c, max_leaves)
    x = _x(seed + 1, b, d)
    ref = predict_forest(f, x)
    got = np.asarray(forest_eval(x, t.thr, t.fid, t.mask_lo, t.mask_hi, t.leaves)[0])
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 1000),
    block_b=st.sampled_from([1, 2, 4, 8]),
    block_m=st.sampled_from([1, 2, 4, 8]),
)
def test_kernel_tiling_invariant(seed, block_b, block_m):
    """Scores must not depend on the BlockSpec tiling."""
    f, t = _make(seed, 8, 6, 2, 32)
    x = _x(seed, 8, 6)
    whole = np.asarray(forest_eval(x, t.thr, t.fid, t.mask_lo, t.mask_hi, t.leaves)[0])
    tiled = np.asarray(
        forest_eval(x, t.thr, t.fid, t.mask_lo, t.mask_hi, t.leaves,
                    block_b=block_b, block_m=block_m)[0]
    )
    np.testing.assert_allclose(tiled, whole, rtol=1e-5, atol=1e-6)


def test_kernel_int16_matches_quant_oracle():
    scale = 32768.0
    f, t = _make(7, 10, 6, 2, 32)
    x = _x(8, 24, 6)
    qthr, qleaves = quantize_tensors(t.thr, t.leaves, scale)
    qx = quantize_features(x, scale)
    got_i32 = np.asarray(
        forest_eval(qx, qthr, t.fid, t.mask_lo, t.mask_hi, qleaves)[0]
    )
    assert got_i32.dtype == np.int32
    got = got_i32.astype(np.float32) / scale
    ref = predict_forest_quant(f, x, scale)
    np.testing.assert_allclose(got, ref, rtol=1e-6, atol=1e-6)


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 5000), max_leaves=st.sampled_from([8, 32, 64]))
def test_kernel_int16_sweep(seed, max_leaves):
    scale = 4096.0  # coarser scale: exercises real quantization collisions
    f, t = _make(seed, 6, 5, 3, max_leaves)
    x = _x(seed + 9, 12, 5)
    qthr, qleaves = quantize_tensors(t.thr, t.leaves, scale)
    qx = quantize_features(x, scale)
    got = np.asarray(forest_eval(qx, qthr, t.fid, t.mask_lo, t.mask_hi, qleaves)[0])
    ref = predict_forest_quant(f, x, scale)
    np.testing.assert_allclose(got.astype(np.float32) / scale, ref, rtol=1e-6, atol=1e-6)


def test_jnp_path_equals_kernel_path():
    f, t = _make(11, 9, 7, 4, 32)
    x = _x(12, 20, 7)
    a = np.asarray(forest_eval(x, t.thr, t.fid, t.mask_lo, t.mask_hi, t.leaves)[0])
    b = np.asarray(forest_eval_jnp(x, t.thr, t.fid, t.mask_lo, t.mask_hi, t.leaves)[0])
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)


def test_threshold_boundary_goes_left():
    """x exactly at a threshold must take the left branch (x <= t)."""
    f, t = _make(13, 4, 3, 1, 8)
    # Build an instance hitting thresholds exactly.
    x = np.full((1, 3), t.thr[0, 0], np.float32)
    ref = predict_forest(f, x)
    got = np.asarray(forest_eval(x, t.thr, t.fid, t.mask_lo, t.mask_hi, t.leaves)[0])
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)


def test_single_node_trees():
    f, t = _make(14, 5, 4, 2, 2)
    x = _x(15, 10, 4)
    ref = predict_forest(f, x)
    got = np.asarray(forest_eval(x, t.thr, t.fid, t.mask_lo, t.mask_hi, t.leaves)[0])
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)
