"""Structural tests of the forest generator and QuickScorer tensor encoding."""

import json

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.forest import (
    Forest,
    encode_qs,
    load_forest,
    random_forest,
    save_forest,
)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), max_leaves=st.integers(2, 64))
def test_random_tree_leaf_numbering_inorder(seed, max_leaves):
    f = random_forest(seed=seed, n_trees=3, n_features=4, n_classes=2,
                      max_leaves=max_leaves)
    for t in f.trees:
        # left_leaf_ranges asserts in-order numbering internally.
        ranges = t.left_leaf_ranges()
        assert len(ranges) == t.n_nodes
        for b, e in ranges:
            assert e > b


def test_encode_masks_zero_exactly_left_subtree():
    f = random_forest(seed=5, n_trees=2, n_features=3, n_classes=1, max_leaves=16)
    t = encode_qs(f)
    for ti, tree in enumerate(f.trees):
        ranges = tree.left_leaf_ranges()
        for ni, (b, e) in enumerate(ranges):
            mask = int(t.mask_lo[ti, ni]) | (int(t.mask_hi[ti, ni]) << 32)
            for bit in range(64):
                expect = 0 if b <= bit < e else 1
                assert (mask >> bit) & 1 == expect, (ti, ni, bit)


def test_encode_padding_is_inert():
    f = random_forest(seed=6, n_trees=4, n_features=3, n_classes=2, max_leaves=32)
    t = encode_qs(f)
    for ti, tree in enumerate(f.trees):
        for ni in range(tree.n_nodes, t.thr.shape[1]):
            assert np.isinf(t.thr[ti, ni])
            assert t.mask_lo[ti, ni] == 0xFFFFFFFF
            assert t.mask_hi[ti, ni] == 0xFFFFFFFF
        # Padded leaf rows are zero.
        assert not t.leaves[ti, tree.n_leaves:].any()


def test_forest_json_roundtrip(tmp_path):
    f = random_forest(seed=7, n_trees=3, n_features=5, n_classes=3, max_leaves=16)
    p = tmp_path / "f.json"
    save_forest(f, str(p))
    f2 = load_forest(str(p))
    assert f2.n_trees == f.n_trees
    for a, b in zip(f.trees, f2.trees):
        np.testing.assert_array_equal(a.feature, b.feature)
        np.testing.assert_allclose(a.threshold, b.threshold, rtol=1e-6)
        np.testing.assert_allclose(a.leaf_values, b.leaf_values, rtol=1e-6)
    # And the format field matches the Rust loader's expectation.
    j = json.loads(p.read_text())
    assert j["format"] == "arbors-forest-v1"


def test_exit_leaf_boundary_semantics():
    """Split is x <= t: exactly-at-threshold goes left."""
    import numpy as np
    from compile.forest import Tree

    t = Tree(
        feature=np.array([0], np.int32),
        threshold=np.array([0.5], np.float32),
        left=np.array([-1], np.int32),   # leaf 0
        right=np.array([-2], np.int32),  # leaf 1
        leaf_values=np.array([[1.0], [2.0]], np.float32),
    )
    assert t.exit_leaf(np.array([0.5], np.float32)) == 0
    assert t.exit_leaf(np.array([0.5000001], np.float32)) == 1
