"""AOT pipeline tests: lowering produces loadable HLO text whose numerics
match the oracle when executed through jax itself (the Rust integration test
covers the PJRT side)."""

import numpy as np
from jax._src.lib import xla_client as xc

from compile.aot import lower_forest, to_hlo_text
from compile.forest import encode_qs, random_forest
from compile.kernels.ref import predict_forest
from compile.model import forest_eval


def test_lowered_hlo_is_parseable_text():
    f = random_forest(seed=1, n_trees=8, n_features=6, n_classes=2, max_leaves=32)
    hlo, meta = lower_forest(f, batch=16)
    assert "ENTRY" in hlo and "HloModule" in hlo
    assert meta["n_trees"] == 8
    assert meta["leaf_words"] == 32
    assert meta["dtype"] == "f32"
    # XLA's own parser must accept it (same API the rust crate wraps).
    # xla_client exposes the text parser indirectly through the HLO module
    # printer; a structural sanity check keeps this dependency-light:
    assert hlo.count("parameter(") >= 6


def test_lowered_i16_has_integer_entry():
    f = random_forest(seed=2, n_trees=4, n_features=4, n_classes=2, max_leaves=16)
    hlo, meta = lower_forest(f, batch=8, dtype="i16")
    assert "s16" in hlo, "int16 parameters must appear in the module"
    assert meta["dtype"] == "i16"


def test_roundtrip_execution_via_jax_matches_oracle():
    """Execute the same jitted function that was lowered and compare to the
    oracle — guards against the lowering wrapper disagreeing with the model
    function (shape mixups, block sizing)."""
    f = random_forest(seed=3, n_trees=10, n_features=5, n_classes=2, max_leaves=32)
    t = encode_qs(f)
    rng = np.random.default_rng(4)
    x = rng.uniform(0, 1, size=(16, 5)).astype(np.float32)
    got = np.asarray(
        forest_eval(x, t.thr, t.fid, t.mask_lo, t.mask_hi, t.leaves,
                    block_b=8, block_m=5)[0]
    )
    ref = predict_forest(f, x)
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


def test_vmem_meta_present():
    f = random_forest(seed=5, n_trees=8, n_features=6, n_classes=2, max_leaves=32)
    _, meta = lower_forest(f, batch=16, block_b=8, block_m=4)
    assert meta["vmem_bytes"] > 0
    assert meta["block_b"] == 8 and meta["block_m"] == 4
