"""GEMM (MXU-variant) kernel vs the tree-walk oracle."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.forest import encode_qs, random_forest
from compile.kernels.gemm import encode_gemm, gemm_flops, gemm_forest_eval
from compile.kernels.ref import predict_forest
from compile.model import forest_eval


def _run(f, x, **kw):
    t = encode_gemm(f)
    return np.asarray(
        gemm_forest_eval(x, t["a"], t["thr"], t["b"], t["cnt"], t["leaves"], **kw)
    )


def test_gemm_matches_oracle_basic():
    f = random_forest(seed=1, n_trees=10, n_features=7, n_classes=3, max_leaves=16)
    rng = np.random.default_rng(2)
    x = rng.uniform(0, 1, size=(24, 7)).astype(np.float32)
    got = _run(f, x)
    ref = predict_forest(f, x)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 5000),
    n_trees=st.integers(1, 12),
    d=st.integers(1, 10),
    c=st.integers(1, 4),
    max_leaves=st.sampled_from([2, 8, 16, 32]),
)
def test_gemm_matches_oracle_sweep(seed, n_trees, d, c, max_leaves):
    f = random_forest(seed=seed, n_trees=n_trees, n_features=d, n_classes=c,
                      max_leaves=max_leaves)
    rng = np.random.default_rng(seed + 1)
    x = rng.uniform(0, 1, size=(12, d)).astype(np.float32)
    got = _run(f, x)
    ref = predict_forest(f, x)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


def test_gemm_equals_bitvector_kernel():
    """The two L1 formulations (VPU bitvector vs MXU GEMM) must agree."""
    f = random_forest(seed=9, n_trees=8, n_features=5, n_classes=2, max_leaves=32)
    rng = np.random.default_rng(10)
    x = rng.uniform(0, 1, size=(16, 5)).astype(np.float32)
    g = _run(f, x)
    t = encode_qs(f)
    q = np.asarray(forest_eval(x, t.thr, t.fid, t.mask_lo, t.mask_hi, t.leaves)[0])
    np.testing.assert_allclose(g, q, rtol=1e-4, atol=1e-4)


def test_gemm_tiling_invariant():
    f = random_forest(seed=11, n_trees=8, n_features=4, n_classes=2, max_leaves=8)
    rng = np.random.default_rng(12)
    x = rng.uniform(0, 1, size=(8, 4)).astype(np.float32)
    whole = _run(f, x)
    tiled = _run(f, x, block_b=4, block_m=2)
    np.testing.assert_allclose(tiled, whole, rtol=1e-5, atol=1e-6)


def test_flops_accounting():
    # The tensor formulation's compute blow-up is explicit and positive.
    assert gemm_flops(64, 128, 32, 31, 32, 2) > 64 * 128 * 31
